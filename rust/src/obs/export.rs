//! Exposition: Prometheus-style text, JSON, cross-worker aggregation, and
//! the plain-TCP scrape listener.
//!
//! Everything downstream of the registry speaks one intermediate form:
//! **flat summable series** — `Vec<(name, u64)>` where histograms are
//! expanded to `_count`, `_sum`, and cumulative `_bucket{le="…"}` entries
//! (with a final `le="+Inf"`). The coordinator's cluster view of N
//! workers is a name-keyed combination ([`aggregate`]): plain values sum
//! directly, cumulative buckets are decumulated to exact per-bucket
//! deltas, summed, and re-cumulated (identical to merging the raw
//! histograms), and percentiles are *re-derived* from the combined
//! buckets ([`derive_quantiles`]) rather than averaged (averaging p99s is
//! statistically meaningless; merged buckets give the true cluster-wide
//! distribution at bucket resolution).
//!
//! The scrape endpoint ([`spawn_scrape_listener`]) is a deliberately tiny
//! HTTP/1.0 responder: read one request, answer text (or JSON for paths
//! containing `json`, or the flight recorder's trace dump for paths
//! containing `trace` — checked first, since `/trace.json` contains
//! both), close. No routing, no keep-alive, no dependency —
//! `curl http://addr/metrics` works and that is the whole contract.
//!
//! Renderers treat series names as *data, not markup*: the text form
//! replaces ASCII control characters (a newline inside a label value
//! could forge a whole extra series line) and the JSON form escapes
//! quotes, backslashes, and control characters per RFC 8259 — hostile
//! label values render escaped, never structurally.

use super::registry::{Registry, Sample};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// Expand every registered metric into flat summable series (sorted by
/// name): counters and gauges verbatim, histograms as `_count` / `_sum` /
/// cumulative nonzero `_bucket{le="…"}` / `_bucket{le="+Inf"}`.
pub fn flatten(reg: &Registry) -> Vec<(String, u64)> {
    let mut out = Vec::new();
    for (name, sample) in reg.snapshot() {
        match sample {
            Sample::Counter(v) | Sample::Gauge(v) => out.push((name, v)),
            Sample::Hist(h) => {
                out.push((format!("{name}_count"), h.count()));
                out.push((format!("{name}_sum"), h.sum));
                let mut cum = 0u64;
                for (b, &n) in h.buckets.iter().enumerate() {
                    if n > 0 {
                        cum += n;
                        out.push((
                            format!("{name}_bucket{{le=\"{}\"}}", super::hist::bucket_upper(b)),
                            cum,
                        ));
                    }
                }
                out.push((format!("{name}_bucket{{le=\"+Inf\"}}"), cum));
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Combine flat series images — the cluster view of N workers. Only
/// valid on [`flatten`]-shaped input. Counters, gauges, `_count` and
/// `_sum` series sum by name. Cumulative `_bucket{le="…"}` series do NOT
/// sum directly: [`flatten`] omits empty buckets, so a worker with values
/// only in later buckets contributes nothing to an earlier bound another
/// worker emitted, under-counting it. Each image is therefore
/// *decumulated* into exact per-bucket deltas first, the deltas summed by
/// `(histogram, bound)`, and the result re-cumulated — identical to
/// merging the raw histograms.
pub fn aggregate(images: &[Vec<(String, u64)>]) -> Vec<(String, u64)> {
    use std::collections::BTreeMap;
    let mut sums: BTreeMap<String, u64> = BTreeMap::new();
    // per-histogram per-bucket counts, summed across images
    let mut hists: BTreeMap<String, BTreeMap<u64, u64>> = BTreeMap::new();
    for image in images {
        let mut buckets: BTreeMap<&str, Vec<(u64, u64)>> = BTreeMap::new();
        for (name, v) in image {
            if let Some((prefix, bound)) = bucket_bound(name) {
                buckets.entry(prefix).or_default().push((bound, *v));
            } else {
                *sums.entry(name.clone()).or_insert(0) += v;
            }
        }
        for (prefix, mut bs) in buckets {
            bs.sort_unstable();
            let mut prev = 0u64;
            for (bound, cum) in bs {
                let delta = cum.saturating_sub(prev);
                prev = cum;
                *hists
                    .entry(prefix.to_string())
                    .or_default()
                    .entry(bound)
                    .or_insert(0) += delta;
            }
        }
    }
    let mut out: Vec<(String, u64)> = sums.into_iter().collect();
    for (prefix, bounds) in hists {
        // re-cumulate in bound order; `+Inf` (u64::MAX) sorts last and
        // lands back on the total, so the image stays flatten-shaped
        let mut cum = 0u64;
        for (bound, n) in bounds {
            cum += n;
            let le = if bound == u64::MAX {
                "+Inf".to_string()
            } else {
                bound.to_string()
            };
            out.push((format!("{prefix}_bucket{{le=\"{le}\"}}"), cum));
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Parse the `le` bound out of a `…_bucket{le="…"}` series name.
fn bucket_bound(name: &str) -> Option<(&str, u64)> {
    let open = name.find("_bucket{le=\"")?;
    let prefix = &name[..open];
    let rest = &name[open + "_bucket{le=\"".len()..];
    let le = rest.strip_suffix("\"}")?;
    let bound = if le == "+Inf" {
        u64::MAX
    } else {
        le.parse().ok()?
    };
    Some((prefix, bound))
}

/// Re-derive `_p50` / `_p95` / `_p99` series from the cumulative bucket
/// series in a flat image — how percentiles are reported for aggregated
/// (multi-worker) data, where the raw histograms live in other processes.
pub fn derive_quantiles(flat: &[(String, u64)]) -> Vec<(String, u64)> {
    // group (le, cum) pairs per histogram prefix
    let mut groups: std::collections::BTreeMap<&str, Vec<(u64, u64)>> =
        std::collections::BTreeMap::new();
    for (name, v) in flat {
        if let Some((prefix, bound)) = bucket_bound(name) {
            groups.entry(prefix).or_default().push((bound, *v));
        }
    }
    let mut out = Vec::new();
    for (prefix, mut buckets) in groups {
        buckets.sort_unstable();
        let total = buckets.last().map(|&(_, c)| c).unwrap_or(0);
        for (label, q) in [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)] {
            let bound = if total == 0 {
                0
            } else {
                let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
                buckets
                    .iter()
                    .find(|&&(_, cum)| cum >= rank)
                    .map(|&(le, _)| le)
                    .unwrap_or(u64::MAX)
            };
            out.push((format!("{prefix}_{label}"), bound));
        }
    }
    out
}

/// Render flat series as exposition text: one `name value` line each.
/// Control characters in a name are replaced with `?` — a newline (or
/// carriage return, or escape) inside a label value must not be able to
/// forge extra lines in the exposition.
pub fn render_pairs_text(pairs: &[(String, u64)]) -> String {
    let mut s = String::new();
    for (name, v) in pairs {
        for ch in name.chars() {
            s.push(if ch.is_control() { '?' } else { ch });
        }
        s.push(' ');
        s.push_str(&v.to_string());
        s.push('\n');
    }
    s
}

/// Full flat image plus derived percentiles, sorted by name.
fn full_pairs(reg: &Registry) -> Vec<(String, u64)> {
    let mut pairs = flatten(reg);
    pairs.extend(derive_quantiles(&pairs));
    pairs.sort_by(|a, b| a.0.cmp(&b.0));
    pairs
}

/// Prometheus-style text exposition of a registry (flat series plus
/// derived `_p50/_p95/_p99` lines).
pub fn render_text(reg: &Registry) -> String {
    render_pairs_text(&full_pairs(reg))
}

/// JSON object exposition (`{"name": value, …}`) of the same series as
/// [`render_text`] — what bench rows embed as counter evidence.
pub fn render_json(reg: &Registry) -> String {
    render_pairs_json(&full_pairs(reg))
}

/// Render flat series as a JSON object.
pub fn render_pairs_json(pairs: &[(String, u64)]) -> String {
    let mut s = String::from("{");
    for (i, (name, v)) in pairs.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        json_escape_into(&mut s, name);
        s.push(':');
        s.push_str(&v.to_string());
    }
    s.push('}');
    s
}

/// Append `s` as a quoted JSON string, escaping per RFC 8259: `"`, `\`,
/// and every control character below U+0020 (`\n`/`\r`/`\t` get their
/// short forms, the rest `\u00XX`). Shared by the metrics and trace
/// renderers so one hardening covers both documents.
pub(crate) fn json_escape_into(out: &mut String, s: &str) {
    use std::fmt::Write;
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Answer one scrape connection: read the request head, write the
/// exposition, close.
fn serve_scrape(mut conn: TcpStream, reg: &Registry) {
    let _ = conn.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = conn.set_write_timeout(Some(Duration::from_secs(2)));
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    // read until the blank line ending the request head (curl sends one
    // immediately; a bare `nc` probe that closes early is fine too)
    loop {
        match conn.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
                    break;
                }
            }
        }
    }
    let request_line = head.split(|&b| b == b'\r').next().unwrap_or(&[]);
    // `/trace.json` contains `json`, so the trace route is checked first
    let trace = request_line.windows(5).any(|w| w == b"trace");
    let json = request_line.windows(4).any(|w| w == b"json");
    let (body, ctype) = if trace {
        (super::trace::recorder().to_json(), "application/json")
    } else if json {
        (render_json(reg), "application/json")
    } else {
        (render_text(reg), "text/plain; version=0.0.4")
    };
    let _ = write!(
        conn,
        "HTTP/1.1 200 OK\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = conn.flush();
}

/// Register the `mm_build_info{version="…",simd="on|off"}` constant-1
/// series in the **global** registry (idempotent — the gauge is set, not
/// summed, so repeated calls are harmless). It rides in every scrape and
/// proto `STATS` reply, making a mixed-binary or mixed-SIMD-tier fleet
/// visible in the aggregated cluster view: N workers on one build sum to
/// exactly N; any other total means the fleet disagrees about what it is
/// running.
pub fn register_build_info() {
    let simd = if crate::exec::intersect::simd_active() {
        "on"
    } else {
        "off"
    };
    crate::obs::global()
        .gauge(&format!(
            "mm_build_info{{version=\"{}\",simd=\"{simd}\"}}",
            env!("CARGO_PKG_VERSION")
        ))
        .set(1);
}

/// Bind `addr` and serve the **global** registry to every connection on a
/// detached thread, forever (paths containing `trace` serve the global
/// flight recorder instead — see [`super::trace`]). Returns the bound
/// address (so `--metrics 127.0.0.1:0` reports the ephemeral port it
/// got).
pub fn spawn_scrape_listener(addr: &str) -> std::io::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("mm-metrics".into())
        .spawn(move || {
            for conn in listener.incoming().flatten() {
                serve_scrape(conn, super::global());
            }
        })
        .expect("spawn metrics listener");
    Ok(bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;

    fn sample_registry() -> Registry {
        let r = Registry::new();
        r.counter("mm_store_hits_total").add(3);
        r.counter("mm_store_misses_total").add(2);
        r.gauge("mm_wal_queue_depth").set(1);
        let h = r.histogram("mm_batch_us");
        for v in [10u64, 20, 3000] {
            h.record(v);
        }
        r
    }

    #[test]
    fn flatten_expands_histograms_summably() {
        let flat = flatten(&sample_registry());
        let get = |n: &str| {
            flat.iter()
                .find(|(name, _)| name == n)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("missing series {n} in {flat:?}"))
        };
        assert_eq!(get("mm_store_hits_total"), 3);
        assert_eq!(get("mm_wal_queue_depth"), 1);
        assert_eq!(get("mm_batch_us_count"), 3);
        assert_eq!(get("mm_batch_us_sum"), 3030);
        // 10 → bucket [8,15], 20 → [16,31], 3000 → [2048,4095]; cumulative
        assert_eq!(get("mm_batch_us_bucket{le=\"15\"}"), 1);
        assert_eq!(get("mm_batch_us_bucket{le=\"31\"}"), 2);
        assert_eq!(get("mm_batch_us_bucket{le=\"4095\"}"), 3);
        assert_eq!(get("mm_batch_us_bucket{le=\"+Inf\"}"), 3);
    }

    #[test]
    fn aggregate_sums_and_requantiles() {
        let a = flatten(&sample_registry());
        let b = flatten(&sample_registry());
        let sum = aggregate(&[a, b]);
        let get = |n: &str| {
            sum.iter()
                .find(|(name, _)| name == n)
                .map(|&(_, v)| v)
                .unwrap()
        };
        assert_eq!(get("mm_store_hits_total"), 6);
        assert_eq!(get("mm_batch_us_count"), 6);
        assert_eq!(get("mm_batch_us_bucket{le=\"+Inf\"}"), 6);
        let qs = derive_quantiles(&sum);
        let q = |n: &str| {
            qs.iter()
                .find(|(name, _)| name == n)
                .map(|&(_, v)| v)
                .unwrap()
        };
        // 6 values: 10,10,20,20,3000,3000 → rank 3 (p50) is 20 → le=31
        assert_eq!(q("mm_batch_us_p50"), 31);
        assert_eq!(q("mm_batch_us_p99"), 4095);
    }

    #[test]
    fn aggregate_is_exact_on_disjoint_bucket_support() {
        // worker A's values land in buckets B skipped and vice versa:
        // a plain name-keyed sum of the cumulative series would miss A's
        // carried-forward count at B's bounds and skew percentiles upward
        let a = Registry::new();
        for v in [10u64, 3000, 3000] {
            a.histogram("mm_x_us").record(v); // buckets le=15, le=4095
        }
        let b = Registry::new();
        for v in [20u64, 20] {
            b.histogram("mm_x_us").record(v); // bucket le=31 only
        }
        let sum = aggregate(&[flatten(&a), flatten(&b)]);
        let get = |n: &str| {
            sum.iter()
                .find(|(name, _)| name == n)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("missing {n} in {sum:?}"))
        };
        // merged values 10,20,20,3000,3000 — cumulative counts must match
        // merging the raw histograms, not the naive per-name sum
        assert_eq!(get("mm_x_us_bucket{le=\"15\"}"), 1);
        assert_eq!(get("mm_x_us_bucket{le=\"31\"}"), 3);
        assert_eq!(get("mm_x_us_bucket{le=\"4095\"}"), 5);
        assert_eq!(get("mm_x_us_bucket{le=\"+Inf\"}"), 5);
        let qs = derive_quantiles(&sum);
        let p50 = qs.iter().find(|(n, _)| n == "mm_x_us_p50").unwrap().1;
        assert_eq!(p50, 31, "rank-3 value is 20 → bucket le=31");
    }

    #[test]
    fn text_and_json_render() {
        let r = sample_registry();
        let text = render_text(&r);
        assert!(text.contains("mm_store_hits_total 3\n"), "{text}");
        assert!(text.contains("mm_batch_us_p50 31\n"), "{text}");
        assert!(text.contains("mm_batch_us_bucket{le=\"+Inf\"} 3\n"), "{text}");
        let json = render_json(&r);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"mm_store_hits_total\":3"), "{json}");
        assert!(
            json.contains("\"mm_batch_us_bucket{le=\\\"+Inf\\\"}\":3"),
            "{json}"
        );
    }

    #[test]
    fn hostile_names_render_escaped_or_replaced() {
        // label values are attacker-influenced in principle (worker
        // addresses, file paths); the renderers must treat them as data
        let evil = vec![
            ("mm_x{label=\"quote\\\"here\"}".to_string(), 1u64),
            ("mm_x{label=\"back\\\\slash\"}".to_string(), 2u64),
            ("mm_x{label=\"new\nline\"} forged_series 999".to_string(), 3u64),
            ("mm_x{label=\"tab\there\"}".to_string(), 4u64),
            ("mm_x{label=\"esc\u{1b}[2J\"}".to_string(), 5u64),
        ];
        let text = render_pairs_text(&evil);
        // exactly one line per series: the newline could not forge one
        assert_eq!(text.lines().count(), evil.len(), "{text}");
        assert!(!text.contains("forged_series 999\n"), "{text}");
        assert!(text.contains("new?line"), "{text}");
        assert!(text.contains("tab?here"), "{text}");
        assert!(!text.contains('\u{1b}'), "{text}");
        let json = render_pairs_json(&evil);
        // structurally valid: no raw control bytes, quotes and
        // backslashes escaped, braces only as literal characters inside
        // strings (which escaping has made inert)
        assert!(!json.contains('\n') && !json.contains('\t') && !json.contains('\u{1b}'), "{json}");
        assert!(json.contains("quote\\\"here"), "{json}");
        assert!(json.contains("back\\\\slash"), "{json}");
        assert!(json.contains("new\\nline"), "{json}");
        assert!(json.contains("esc\\u001b"), "{json}");
        // every value still present and keyed
        for (_, v) in &evil {
            assert!(json.contains(&format!(":{v}")), "{json}");
        }
    }

    #[test]
    fn scrape_listener_answers_http() {
        // exercises the listener end to end over loopback — but against
        // the process-global registry, so only presence is asserted
        crate::obs::global().counter("mm_scrape_selftest_total").inc();
        let addr = spawn_scrape_listener("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(conn, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("mm_scrape_selftest_total"), "{resp}");
        // JSON flavor
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(conn, "GET /metrics.json HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.contains("application/json"), "{resp}");
        assert!(resp.contains("\"mm_scrape_selftest_total\":"), "{resp}");
        // the trace route wins over the json substring it contains
        let mut b = crate::obs::TraceBuilder::with_id(0x5CA1AB1E);
        b.span(0, "batch", 0, 10, String::new());
        crate::obs::trace::recorder().record(b.finish(), false);
        let mut conn = TcpStream::connect(addr).unwrap();
        write!(conn, "GET /trace.json HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.contains("application/json"), "{resp}");
        assert!(resp.contains("\"recent\":["), "{resp}");
        assert!(resp.contains("000000005ca1ab1e"), "{resp}");
        assert!(!resp.contains("mm_scrape_selftest_total"), "{resp}");
    }

    #[test]
    fn build_info_series_rides_every_exposition() {
        register_build_info();
        register_build_info(); // idempotent
        let text = render_text(crate::obs::global());
        let line = text
            .lines()
            .find(|l| l.starts_with("mm_build_info{"))
            .unwrap_or_else(|| panic!("no mm_build_info in {text}"));
        assert!(line.ends_with(" 1"), "{line}");
        assert!(line.contains(&format!("version=\"{}\"", env!("CARGO_PKG_VERSION"))), "{line}");
        assert!(line.contains("simd=\"on\"") || line.contains("simd=\"off\""), "{line}");
        // the flat STATS form carries it too
        let flat = flatten(crate::obs::global());
        assert!(flat.iter().any(|(n, v)| n.starts_with("mm_build_info{") && *v == 1));
    }
}
