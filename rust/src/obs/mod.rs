//! UNIFIED OBSERVABILITY — one dependency-free metrics core for all five
//! layers.
//!
//! Until now the system's only runtime visibility was a hand-rolled
//! `fabric:` line and the per-store hit/miss totals — every layer counted
//! (or didn't) its own way. This module is the single counter
//! implementation they all fold onto:
//!
//! * [`registry`] — atomic [`Counter`]s and [`Gauge`]s plus a process-wide
//!   name → metric [`Registry`] ([`global()`]). Metrics are `Arc`-shared:
//!   a component can own its counters privately (the
//!   [`ResultStore`](crate::service::ResultStore) does, so per-instance
//!   snapshots stay exact) and *also* register the same atomics for
//!   scraping — registration is a pointer, never a copy, so the scrape
//!   view is always live.
//! * [`hist`] — log2-bucketed latency [`Histogram`]s: one atomic per
//!   power-of-two bucket, mergeable snapshots, p50/p95/p99 extraction.
//!   Recording is one relaxed `fetch_add` per bucket — cheap enough for
//!   per-request paths; percentiles are computed at read time.
//! * [`span`] — scoped [`Span`] timers that record their elapsed time into
//!   a histogram on drop (unwind-safe: a panicking request still counts).
//! * [`trace`] — per-batch distributed [`Trace`]s: span trees assembled
//!   across the shard fabric (proto v5 carries the trace context down and
//!   the worker's child spans back up) plus the [`FlightRecorder`] that
//!   retains recent and slow traces for `/trace.json` / `--trace-tree`.
//! * [`export`] — Prometheus-style text + JSON exposition, the flat
//!   summable series form the proto v4 `STATS` reply carries, cross-worker
//!   aggregation (sums by name, re-derives percentiles from summed
//!   buckets), and the plain-TCP scrape listener behind
//!   `--metrics <addr>` (which also serves the flight recorder at
//!   `/trace.json`).
//!
//! # Series naming scheme
//!
//! `mm_<layer>_<what>[_total|_us]{label="v"}` — layer prefixes are
//! `kernel` (tier dispatch), `fused` (trie walk), `store` / `planner`
//! (morph-aware cache), `wal` / `batch` (durable service), `shard` /
//! `worker` (distribution fabric). `_total` marks monotone counters,
//! `_us` marks microsecond histograms (expanded on export to `_count`,
//! `_sum`, cumulative `_bucket{le="…"}`, and derived `_p50/_p95/_p99`).
//! Labels are baked into the series name — the registry is a flat
//! namespace, which is exactly what lets cross-worker aggregation combine
//! by name ([`aggregate`]: plain series sum, buckets merge exactly).
//!
//! Hot-path call sites cache their handle once via [`obs_counter!`] /
//! [`obs_gauge!`] / [`obs_histogram!`] so steady-state cost is a single
//! relaxed atomic op, with the registry lock touched only on first use.

pub mod export;
pub mod hist;
pub mod registry;
pub mod span;
pub mod trace;

pub use export::{
    aggregate, derive_quantiles, flatten, register_build_info, render_json, render_text,
    spawn_scrape_listener,
};
pub use hist::{HistSnapshot, Histogram};
pub use registry::{global, Counter, Gauge, Registry, Sample};
pub use span::Span;
pub use trace::{FlightRecorder, SpanRecord, Trace, TraceBuilder};

/// Cached global counter handle: expands to a `&'static`-lifetime lookup
/// whose registry access happens once per call site.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<std::sync::Arc<$crate::obs::Counter>> =
            std::sync::OnceLock::new();
        &**HANDLE.get_or_init(|| $crate::obs::global().counter($name))
    }};
}

/// Cached global gauge handle (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<std::sync::Arc<$crate::obs::Gauge>> =
            std::sync::OnceLock::new();
        &**HANDLE.get_or_init(|| $crate::obs::global().gauge($name))
    }};
}

/// Cached global histogram handle (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_histogram {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<std::sync::Arc<$crate::obs::Histogram>> =
            std::sync::OnceLock::new();
        &**HANDLE.get_or_init(|| $crate::obs::global().histogram($name))
    }};
}
