//! Scoped span timers: time a region by holding a value.
//!
//! A [`Span`] records its elapsed wall time into a [`Histogram`] when
//! dropped — including on unwind, so a panicking request is still counted
//! (a crash that silently vanishes from the latency distribution is how
//! p99s lie). For regions whose attribution is decided late (e.g. a batch
//! that only turns out to be slow at the end), [`Span::cancel`] discards
//! the measurement and [`Span::finish`] ends it early and returns the
//! elapsed time.
//!
//! Distinct from a *trace* span ([`super::trace::SpanRecord`]): a [`Span`]
//! aggregates into a latency distribution and forgets the individual
//! event; a trace span is the individual event, kept with its causal
//! parent so one batch's tree can be reconstructed. The two are fed by
//! the same measurements — a region worth a histogram is usually worth a
//! node in the slow-query flight recorder too.

use super::hist::Histogram;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Live timer bound to a histogram.
#[derive(Debug)]
pub struct Span {
    hist: Option<Arc<Histogram>>,
    start: Instant,
}

impl Span {
    /// Start timing; the drop records into `hist`.
    pub fn start(hist: Arc<Histogram>) -> Span {
        Span {
            hist: Some(hist),
            start: Instant::now(),
        }
    }

    /// Elapsed time so far (the span keeps running).
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Record now instead of at scope end; returns the recorded duration.
    pub fn finish(mut self) -> Duration {
        let d = self.start.elapsed();
        if let Some(h) = self.hist.take() {
            h.record_duration(d);
        }
        d
    }

    /// Drop without recording.
    pub fn cancel(mut self) {
        self.hist = None;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(h) = self.hist.take() {
            h.record_duration(self.start.elapsed());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_on_drop() {
        let h = Arc::new(Histogram::new());
        {
            let _s = Span::start(h.clone());
            std::thread::sleep(Duration::from_millis(1));
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 1);
        assert!(snap.sum >= 1000, "at least 1ms in micros, got {}", snap.sum);
    }

    #[test]
    fn span_records_on_panic_unwind() {
        let h = Arc::new(Histogram::new());
        let hc = h.clone();
        let result = std::panic::catch_unwind(move || {
            let _s = Span::start(hc);
            panic!("boom");
        });
        assert!(result.is_err());
        assert_eq!(h.snapshot().count(), 1, "unwind still records");
    }

    #[test]
    fn finish_and_cancel() {
        let h = Arc::new(Histogram::new());
        let d = Span::start(h.clone()).finish();
        assert_eq!(h.snapshot().count(), 1);
        assert!(d >= Duration::ZERO);
        Span::start(h.clone()).cancel();
        assert_eq!(h.snapshot().count(), 1, "cancelled span not recorded");
    }
}
