//! Incremental motif counting over a stream of edge updates — one of the
//! paper's "other applications of pattern morphing" (§1): because morphing
//! is a linear algebra over counts, deltas convert between edge- and
//! vertex-induced views the same way totals do, so the maintained state can
//! be either basis.
//!
//! For an inserted/deleted edge `(u, v)`, only vertex sets containing both
//! endpoints change their induced structure. The counter enumerates the
//! connected `k`-subsets around the edge, classifies each set's induced
//! pattern before and after the flip, and applies the ± delta.

use crate::graph::{DynGraph, VertexId};
use crate::pattern::canon::CanonKey;
use crate::pattern::{catalog, Pattern};
use std::collections::HashMap;

/// Streaming motif counter for motifs of `size` vertices (3..=5).
pub struct IncrementalMotifCounter {
    graph: DynGraph,
    size: usize,
    /// motif canonical key → index into `counts`
    index: HashMap<CanonKey, usize>,
    motifs: Vec<Pattern>,
    counts: Vec<i64>,
}

impl IncrementalMotifCounter {
    /// Start from an existing graph; initial counts via the batch engine.
    pub fn new(graph: DynGraph, size: usize, threads: usize) -> IncrementalMotifCounter {
        assert!((3..=5).contains(&size));
        let motifs = catalog::motifs_vertex_induced(size);
        let snapshot = graph.to_data_graph("incremental-base");
        let batch =
            super::count_motifs(&snapshot, size, crate::morph::Policy::Naive, threads);
        let mut index = HashMap::new();
        let mut counts = Vec::new();
        for (i, m) in motifs.iter().enumerate() {
            index.insert(m.canonical_key(), i);
            counts.push(batch.get(m).unwrap() as i64);
        }
        IncrementalMotifCounter {
            graph,
            size,
            index,
            motifs,
            counts,
        }
    }

    pub fn graph(&self) -> &DynGraph {
        &self.graph
    }

    /// Graph epoch after the updates applied so far (delegates to
    /// [`DynGraph::version`]; no-op inserts/removes leave it unchanged).
    /// The service layer keys its result cache by this value, so streaming
    /// updates through this counter and batch queries through
    /// [`crate::service::Service`] can never mix counts from different
    /// graph states.
    pub fn version(&self) -> u64 {
        self.graph.version()
    }

    /// Current counts, aligned with [`Self::motifs`].
    pub fn counts(&self) -> Vec<(Pattern, u64)> {
        self.motifs
            .iter()
            .cloned()
            .zip(self.counts.iter().map(|&c| {
                debug_assert!(c >= 0, "negative incremental count");
                c as u64
            }))
            .collect()
    }

    /// Insert an edge and update counts. Returns false if it already
    /// existed.
    pub fn insert_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        if self.graph.has_edge(u, v) || u == v {
            return false;
        }
        self.graph.insert_edge(u, v);
        self.apply_delta(u, v, /*inserted=*/ true);
        true
    }

    /// Remove an edge and update counts. Returns false if absent.
    pub fn remove_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        if !self.graph.has_edge(u, v) {
            return false;
        }
        // classify with the edge still present, then flip
        self.apply_delta(u, v, /*inserted=*/ false);
        self.graph.remove_edge(u, v);
        true
    }

    /// Enumerate connected `size`-subsets containing `{u, v}` in the graph
    /// *with* the edge present, and apply ± deltas for the induced pattern
    /// with and without `(u, v)`.
    fn apply_delta(&mut self, u: VertexId, v: VertexId, inserted: bool) {
        let k = self.size;
        let mut set: Vec<VertexId> = vec![u, v];
        let mut sets: Vec<Vec<VertexId>> = Vec::new();
        collect_connected_supersets(&self.graph, &mut set, k, &mut sets);
        for s in sets {
            // induced adjacency with the edge present
            let with = self.classify(&s, None);
            // structure without (u,v): may be disconnected → not a motif
            let without = self.classify(&s, Some((u, v)));
            let (plus, minus) = if inserted {
                (with, without)
            } else {
                (without, with)
            };
            if let Some(i) = plus {
                self.counts[i] += 1;
            }
            if let Some(i) = minus {
                self.counts[i] -= 1;
            }
        }
    }

    /// Canonical classification of the induced pattern on `s`, optionally
    /// excluding one edge. `None` if disconnected (not a motif).
    fn classify(&self, s: &[VertexId], exclude: Option<(VertexId, VertexId)>) -> Option<usize> {
        let k = s.len();
        let mut p = Pattern::empty(k);
        for i in 0..k {
            for j in (i + 1)..k {
                let is_excluded = exclude.is_some_and(|(a, b)| {
                    (s[i] == a && s[j] == b) || (s[i] == b && s[j] == a)
                });
                if !is_excluded && self.graph.has_edge(s[i], s[j]) {
                    p.add_edge(i, j);
                }
            }
        }
        if !p.is_connected() {
            return None;
        }
        self.index.get(&p.vertex_induced().canonical_key()).copied()
    }
}

/// Enumerate all vertex sets of size `k` that contain `set` (currently the
/// two edge endpoints) and are connected in `g`, without duplicates:
/// extend only with neighbors of the current set, requiring each added
/// vertex to be greater than the previously *added* vertex unless it only
/// became reachable through it (standard connected-subgraph enumeration:
/// we keep it simple and dedupe via sorting since k ≤ 5).
fn collect_connected_supersets(
    g: &DynGraph,
    set: &mut Vec<VertexId>,
    k: usize,
    out: &mut Vec<Vec<VertexId>>,
) {
    if set.len() == k {
        let mut s = set.clone();
        s.sort_unstable();
        out.push(s);
        return;
    }
    // candidate extensions: neighbors of any member, larger dedupe later
    let mut cands: Vec<VertexId> = Vec::new();
    for &w in set.iter() {
        for &x in g.neighbors(w) {
            if !set.contains(&x) && !cands.contains(&x) {
                cands.push(x);
            }
        }
    }
    for x in cands {
        set.push(x);
        collect_connected_supersets(g, set, k, out);
        set.pop();
    }
    if set.len() == 2 {
        // dedupe complete enumeration (sets reached via multiple orders)
        out.sort();
        out.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::morph::Policy;
    use crate::util::proptest;

    fn assert_counts_match_batch(inc: &IncrementalMotifCounter, size: usize) {
        let snapshot = inc.graph().to_data_graph("check");
        let batch = super::super::count_motifs(&snapshot, size, Policy::Naive, 1);
        for (p, c) in inc.counts() {
            assert_eq!(
                c,
                batch.get(&p).unwrap(),
                "motif {p:?} after updates ({} v, {} e)",
                snapshot.num_vertices(),
                snapshot.num_edges()
            );
        }
    }

    #[test]
    fn insertions_match_batch_recount() {
        let g0 = erdos_renyi(25, 60, 0xADD);
        let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 4, 1);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..15 {
            let u = rng.below(25) as u32;
            let v = rng.below(25) as u32;
            if u != v {
                inc.insert_edge(u, v);
            }
        }
        assert_counts_match_batch(&inc, 4);
    }

    #[test]
    fn deletions_match_batch_recount() {
        let g0 = erdos_renyi(25, 90, 0xDE1);
        let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 4, 1);
        // delete 10 existing edges
        let edges: Vec<(u32, u32)> = (0..25)
            .flat_map(|v| g0.neighbors(v).iter().map(move |&u| (v, u)))
            .filter(|&(v, u)| v < u)
            .take(10)
            .collect();
        for (u, v) in edges {
            assert!(inc.remove_edge(u, v));
        }
        assert_counts_match_batch(&inc, 4);
    }

    #[test]
    fn mixed_stream_sizes_3_and_5() {
        for size in [3usize, 5] {
            let g0 = erdos_renyi(18, 40, size as u64);
            let mut inc =
                IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), size, 1);
            let mut rng = crate::util::rng::Rng::new(7);
            for step in 0..12 {
                let u = rng.below(18) as u32;
                let v = rng.below(18) as u32;
                if u == v {
                    continue;
                }
                if step % 3 == 2 {
                    inc.remove_edge(u, v);
                } else {
                    inc.insert_edge(u, v);
                }
            }
            assert_counts_match_batch(&inc, size);
        }
    }

    #[test]
    fn prop_random_streams() {
        proptest::check(0x57E4, 8, |rng| {
            let n = 12 + rng.below_usize(8);
            let g0 = erdos_renyi(n, 2 * n, rng.next_u64());
            let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 4, 1);
            for _ in 0..10 {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                if u == v {
                    continue;
                }
                if rng.chance(0.35) {
                    inc.remove_edge(u, v);
                } else {
                    inc.insert_edge(u, v);
                }
            }
            assert_counts_match_batch(&inc, 4);
        });
    }

    #[test]
    fn duplicate_operations_are_noops() {
        let g0 = erdos_renyi(10, 20, 3);
        let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 3, 1);
        let before = inc.counts();
        let v0 = inc.version();
        // inserting an existing edge / removing a non-edge: no change
        let (u, v) = (0u32, *g0.neighbors(0).first().expect("vertex 0 has neighbors"));
        assert!(!inc.insert_edge(u, v));
        let non = (0..10u32)
            .flat_map(|a| (0..10u32).map(move |b| (a, b)))
            .find(|&(a, b)| a != b && !g0.has_edge(a, b))
            .unwrap();
        assert!(!inc.remove_edge(non.0, non.1));
        assert_eq!(before, inc.counts());
        assert_eq!(inc.version(), v0, "no-op updates must not bump the epoch");
        // an applied update does bump it
        assert!(inc.insert_edge(non.0, non.1));
        assert_eq!(inc.version(), v0 + 1);
    }

    #[test]
    fn self_loops_are_rejected() {
        let g0 = erdos_renyi(8, 14, 5);
        let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 3, 1);
        let before = inc.counts();
        let v0 = inc.version();
        assert!(!inc.insert_edge(3, 3), "self-loop insert must be refused");
        assert!(!inc.remove_edge(3, 3), "self-loop removal is a no-op");
        assert_eq!(before, inc.counts(), "rejected self-loops must not touch counts");
        assert_eq!(inc.version(), v0, "rejected self-loops must not bump the version");
    }

    #[test]
    fn version_is_monotone_and_bumps_exactly_on_applied_mutations() {
        let g0 = erdos_renyi(12, 24, 9);
        let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 3, 1);
        let mut rng = crate::util::rng::Rng::new(11);
        let mut last = inc.version();
        for _ in 0..40 {
            let u = rng.below(12) as u32;
            let v = rng.below(12) as u32;
            let applied = if rng.below(2) == 0 {
                inc.insert_edge(u, v)
            } else {
                inc.remove_edge(u, v)
            };
            let now = inc.version();
            if applied {
                assert_eq!(now, last + 1, "each applied mutation bumps exactly once");
            } else {
                assert_eq!(now, last, "rejected mutations (dup/missing/self-loop) never bump");
            }
            last = now;
        }
        assert_counts_match_batch(&inc, 3);
    }

    #[test]
    fn removal_deltas_carry_the_negative_sign() {
        // a single triangle: removing one edge must subtract the triangle
        // and add the wedge the surviving two edges induce
        let g0 = crate::graph::GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (0, 2)])
            .build("tri");
        let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 3, 1);
        let count_of = |inc: &IncrementalMotifCounter, q: &Pattern| {
            inc.counts()
                .into_iter()
                .find(|(p, _)| p.canonical_key() == q.canonical_key())
                .map(|(_, c)| c)
                .unwrap()
        };
        let tri = catalog::triangle().vertex_induced();
        let wedge = catalog::path(3).vertex_induced();
        assert_eq!(count_of(&inc, &tri), 1);
        assert_eq!(count_of(&inc, &wedge), 0);
        assert!(inc.remove_edge(0, 1));
        assert_eq!(count_of(&inc, &tri), 0, "removal must subtract the dead triangle");
        assert_eq!(count_of(&inc, &wedge), 1, "…and credit the wedge it leaves behind");
        assert_counts_match_batch(&inc, 3);
        // putting the edge back restores the starting counts exactly
        assert!(inc.insert_edge(0, 1));
        assert_eq!(count_of(&inc, &tri), 1);
        assert_eq!(count_of(&inc, &wedge), 0);
    }
}
