//! Clique counting — the degenerate corner of the morphing lattice (cliques
//! are simultaneously edge- and vertex-induced, so they never morph; the
//! optimizer must leave them alone).

use crate::exec::parallel::par_count_matches;
use crate::graph::DataGraph;
use crate::pattern::catalog;
use crate::plan::Plan;

/// Count k-cliques (unique subgraphs).
pub fn count_cliques(graph: &DataGraph, k: usize, threads: usize) -> u64 {
    assert!((1..=crate::pattern::MAX_PATTERN_VERTICES).contains(&k));
    if k == 1 {
        return graph.num_vertices() as u64;
    }
    if k == 2 {
        return graph.num_edges() as u64;
    }
    let plan = Plan::compile(&catalog::clique(k));
    par_count_matches(graph, &plan, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::GraphBuilder;

    #[test]
    fn cliques_in_k5() {
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        let g = GraphBuilder::new().edges(&edges).build("k5");
        assert_eq!(count_cliques(&g, 1, 1), 5);
        assert_eq!(count_cliques(&g, 2, 1), 10);
        assert_eq!(count_cliques(&g, 3, 2), 10);
        assert_eq!(count_cliques(&g, 4, 2), 5);
        assert_eq!(count_cliques(&g, 5, 2), 1);
    }

    #[test]
    fn matches_brute_force() {
        let g = erdos_renyi(30, 140, 71);
        for k in 3..=4 {
            assert_eq!(
                count_cliques(&g, k, 2),
                crate::exec::brute_force_count(&g, &crate::pattern::catalog::clique(k))
            );
        }
    }
}
