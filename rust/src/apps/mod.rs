//! Graph mining applications built on the matcher + morphing engine:
//! motif counting, frequent subgraph mining, pattern matching and clique
//! finding — the application set of the paper's evaluation (§4.2).

pub mod approx;
pub mod cliques;
pub mod fsm;
pub mod incremental;
pub mod matching;
pub mod motifs;

pub use approx::{approx_motifs, ApproxMotifCounts};
pub use cliques::count_cliques;
pub use fsm::{fsm, FsmConfig, FsmResult};
pub use incremental::IncrementalMotifCounter;
pub use matching::{match_patterns, match_patterns_opts, MatchResult};
pub use motifs::{count_motifs, count_motifs_opts, MotifCounts};
