//! Pattern matching: count (or enumerate) an arbitrary query pattern set,
//! optionally morphed — the `p1^V … p7^V`, `p2^E`, `{p2^E, p3^E}` and
//! `{p5^V, p6^V}` experiments of Table 3.

use crate::agg::{CountAgg, EnumerateAgg};
use crate::graph::{DataGraph, GraphStats, VertexId};
use crate::morph::{self, Policy};
use crate::pattern::Pattern;
use crate::plan::cost::CostParams;
use crate::util::timer::PhaseProfile;

/// Result of a pattern-matching run.
#[derive(Debug)]
pub struct MatchResult {
    /// Unique-match counts, one per query in input order.
    pub counts: Vec<u64>,
    /// Matching vs conversion breakdown.
    pub profile: PhaseProfile,
    /// Alternative pattern set actually matched (Table 4).
    pub alt_set: Vec<Pattern>,
    /// Equation render per query (Fig. 4 style), for reports.
    pub equations: Vec<String>,
}

/// Count matches of `queries` under `policy` (fused co-execution of the
/// alternative pattern set by default).
pub fn match_patterns(
    graph: &DataGraph,
    queries: &[Pattern],
    policy: Policy,
    threads: usize,
) -> MatchResult {
    match_patterns_opts(graph, queries, policy, morph::ExecOpts::new(threads))
}

/// [`match_patterns`] with explicit execution options (fused on/off).
pub fn match_patterns_opts(
    graph: &DataGraph,
    queries: &[Pattern],
    policy: Policy,
    opts: morph::ExecOpts,
) -> MatchResult {
    let mut profile = PhaseProfile::new();
    // one stats instance serves cost-based PMR and fused order selection:
    // reuse the caller's (e.g. the coordinator's cached stats), else
    // compute once and let it ride along in the options
    let mut opts = opts;
    if policy == Policy::CostBased && opts.stats.is_none() {
        opts.stats = Some(profile.time("stats", || GraphStats::compute(graph, 2000, 0x3A7C4)));
    }
    let stats_ref = if policy == Policy::CostBased {
        opts.stats.as_ref()
    } else {
        None
    };
    let plan = profile.time("plan", || {
        morph::plan_queries(queries, policy, stats_ref, &CostParams::counting())
    });
    let values = morph::execute_opts(graph, &plan, &CountAgg, opts, &mut profile);
    let counts = values
        .iter()
        .zip(queries)
        .map(|(&maps, q)| {
            let aut = crate::pattern::iso::automorphisms(q).len() as i128;
            assert!(maps >= 0 && maps % aut == 0, "bad map count {maps} for {q:?}");
            (maps / aut) as u64
        })
        .collect();
    MatchResult {
        counts,
        profile,
        alt_set: plan.base.clone(),
        equations: plan.exprs.iter().map(|e| e.describe()).collect(),
    }
}

/// Enumerate unique matches (as sorted vertex sets per unique subgraph) of a
/// single query, reported in **original** vertex IDs (the inverse of any
/// degree-ordered relabeling applied at graph build time). Materializes all
/// matches — small graphs only.
pub fn enumerate_pattern(
    graph: &DataGraph,
    query: &Pattern,
    policy: Policy,
    threads: usize,
) -> Vec<Vec<VertexId>> {
    let mut profile = PhaseProfile::new();
    let stats;
    let stats_ref = if policy == Policy::CostBased {
        stats = GraphStats::compute(graph, 2000, 0x3A7C5);
        Some(&stats)
    } else {
        None
    };
    let plan = morph::plan_queries(
        std::slice::from_ref(query),
        policy,
        stats_ref,
        &CostParams::enumeration(query.num_vertices()),
    );
    let values = morph::execute(graph, &plan, &EnumerateAgg, threads, &mut profile);
    let ms = &values[0];
    ms.assert_consistent();
    let mut subs: Vec<Vec<VertexId>> = ms
        .unique_subgraphs()
        .into_iter()
        .map(|s| {
            let mut orig: Vec<VertexId> = s.iter().map(|&v| graph.original_id(v)).collect();
            orig.sort_unstable();
            orig
        })
        .collect();
    subs.sort();
    subs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::GraphBuilder;
    use crate::pattern::catalog;

    #[test]
    fn counts_match_across_policies() {
        let g = erdos_renyi(70, 280, 51);
        let queries = vec![
            catalog::cycle(4),
            catalog::diamond().vertex_induced(),
            catalog::house().vertex_induced(),
        ];
        let off = match_patterns(&g, &queries, Policy::Off, 2);
        let naive = match_patterns(&g, &queries, Policy::Naive, 2);
        let cost = match_patterns(&g, &queries, Policy::CostBased, 2);
        assert_eq!(off.counts, naive.counts);
        assert_eq!(off.counts, cost.counts);
    }

    #[test]
    fn enumeration_morphed_equals_direct() {
        let g = erdos_renyi(30, 110, 52);
        for q in [
            catalog::cycle(4),
            catalog::cycle(4).vertex_induced(),
            catalog::tailed_triangle().vertex_induced(),
        ] {
            let direct = enumerate_pattern(&g, &q, Policy::Off, 1);
            let morphed = enumerate_pattern(&g, &q, Policy::Naive, 1);
            assert_eq!(direct, morphed, "{q:?}");
        }
    }

    #[test]
    fn enumeration_on_known_graph() {
        // K4: the 3 unique edge-induced 4-cycles all share the vertex set
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)])
            .build("k4");
        let subs = enumerate_pattern(&g, &catalog::cycle(4), Policy::Naive, 1);
        assert_eq!(subs, vec![vec![0, 1, 2, 3]]);
        // ... but matching maps differ: counts say 3
        let r = match_patterns(&g, &[catalog::cycle(4)], Policy::Naive, 1);
        assert_eq!(r.counts, vec![3]);
    }

    #[test]
    fn equations_and_alt_set_reported() {
        let g = erdos_renyi(40, 150, 53);
        let r = match_patterns(&g, &[catalog::cycle(4)], Policy::Naive, 1);
        assert_eq!(r.alt_set.len(), 3, "C4 morphs into 3 VI patterns");
        assert!(r.equations[0].contains('+'));
    }
}
