//! Approximate motif counting with exact morphing conversion — another of
//! the paper's "other applications" (§1, approximate graph computations):
//! the Aggregation Conversion Theorem is a *linear* map over counts, so it
//! applies verbatim to unbiased estimators — estimate counts in one basis,
//! convert to the other exactly.
//!
//! Estimator: edge-anchored sampling. Sample `M` edges uniformly; for each,
//! enumerate the connected `k`-subsets containing it and classify their
//! induced motif. A motif occurrence with `e(p)` induced edges is seen from
//! `e(p)` anchors, so `count(p) ≈ (m / M) · Σ hits(p) / e(p)` is unbiased.

use crate::graph::{DataGraph, VertexId};
use crate::pattern::canon::CanonKey;
use crate::pattern::{catalog, iso, Pattern};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Approximate vertex-induced motif counts of `size` from `samples`
/// edge anchors.
pub struct ApproxMotifCounts {
    pub motifs: Vec<Pattern>,
    /// Estimated vertex-induced counts (aligned with `motifs`).
    pub estimates: Vec<f64>,
    /// Number of edge anchors actually sampled.
    pub samples: usize,
}

impl ApproxMotifCounts {
    pub fn get(&self, p: &Pattern) -> Option<f64> {
        let key = p.canonical_key();
        self.motifs
            .iter()
            .position(|m| m.canonical_key() == key)
            .map(|i| self.estimates[i])
    }

    /// Convert the vertex-induced estimates to **edge-induced** estimates
    /// through the Match Conversion Theorem's linear system — exactly the
    /// same coefficients used for exact counts (`U[p][q] = |φ|/|Aut(p)|`).
    pub fn edge_induced_estimates(&self) -> Vec<(Pattern, f64)> {
        let k = self.motifs.len();
        let mut out = Vec::with_capacity(k);
        for (i, p) in self.motifs.iter().enumerate() {
            let pe = p.edge_induced();
            let mut total = 0.0;
            for (j, q) in self.motifs.iter().enumerate() {
                let qe = q.edge_induced();
                if qe.num_edges() < pe.num_edges() {
                    continue;
                }
                let phi = iso::phi_count(&pe, &qe) as f64;
                if phi > 0.0 {
                    let aut = iso::automorphisms(&pe).len() as f64;
                    total += phi / aut * self.estimates[j];
                }
            }
            let _ = i;
            out.push((pe, total));
        }
        out
    }
}

/// Run the estimator.
pub fn approx_motifs(g: &DataGraph, size: usize, samples: usize, seed: u64) -> ApproxMotifCounts {
    assert!((3..=5).contains(&size));
    let motifs = catalog::motifs_vertex_induced(size);
    let index: HashMap<CanonKey, usize> = motifs
        .iter()
        .enumerate()
        .map(|(i, m)| (m.canonical_key(), i))
        .collect();
    let edge_counts: Vec<f64> = motifs
        .iter()
        .map(|m| m.edge_induced().num_edges() as f64)
        .collect();

    // flat edge list for uniform sampling
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(g.num_edges());
    for v in 0..g.num_vertices() as VertexId {
        for &u in g.neighbors(v) {
            if v < u {
                edges.push((v, u));
            }
        }
    }
    let m = edges.len();
    let mut rng = Rng::new(seed);
    let mut hits = vec![0f64; motifs.len()];
    let samples = samples.min(m.max(1));
    for _ in 0..samples {
        let (u, v) = edges[rng.below_usize(m)];
        for s in connected_supersets(g, u, v, size) {
            if let Some(&i) = index.get(&classify(g, &s)) {
                hits[i] += 1.0;
            }
        }
    }
    let scale = m as f64 / samples as f64;
    let estimates: Vec<f64> = hits
        .iter()
        .zip(&edge_counts)
        .map(|(h, e)| h * scale / e)
        .collect();
    ApproxMotifCounts {
        motifs,
        estimates,
        samples,
    }
}

/// Connected `k`-subsets containing the edge `(u, v)`.
fn connected_supersets(g: &DataGraph, u: VertexId, v: VertexId, k: usize) -> Vec<Vec<VertexId>> {
    let mut out = Vec::new();
    let mut set = vec![u, v];
    fn rec(g: &DataGraph, set: &mut Vec<VertexId>, k: usize, out: &mut Vec<Vec<VertexId>>) {
        if set.len() == k {
            let mut s = set.clone();
            s.sort_unstable();
            out.push(s);
            return;
        }
        let mut cands: Vec<VertexId> = Vec::new();
        for &w in set.iter() {
            for &x in g.neighbors(w) {
                if !set.contains(&x) && !cands.contains(&x) {
                    cands.push(x);
                }
            }
        }
        for x in cands {
            set.push(x);
            rec(g, set, k, out);
            set.pop();
        }
    }
    rec(g, &mut set, k, &mut out);
    out.sort();
    out.dedup();
    out
}

/// Canonical key of the induced (vertex-induced) pattern on `s`.
fn classify(g: &DataGraph, s: &[VertexId]) -> CanonKey {
    let k = s.len();
    let mut p = Pattern::empty(k);
    for i in 0..k {
        for j in (i + 1)..k {
            if g.has_edge(s[i], s[j]) {
                p.add_edge(i, j);
            }
        }
    }
    p.vertex_induced().canonical_key()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::morph::Policy;

    #[test]
    fn full_sampling_is_exact() {
        // sampling every edge once ≠ exhaustive (sampling with replacement),
        // but anchoring at ALL edges deterministically would be exact; with
        // samples == m the estimator is still unbiased — instead check the
        // structure against exact counts with generous tolerance.
        let g = erdos_renyi(60, 300, 0xAB);
        let exact = super::super::count_motifs(&g, 4, Policy::Naive, 2);
        let approx = approx_motifs(&g, 4, 300, 1);
        for (p, c) in &exact.counts {
            let e = approx.get(p).unwrap();
            let c = *c as f64;
            if c > 50.0 {
                let rel = (e - c).abs() / c;
                assert!(rel < 0.5, "{p:?}: exact {c} est {e} rel {rel}");
            }
        }
    }

    #[test]
    fn estimator_converges_with_samples() {
        let g = erdos_renyi(80, 480, 0xCD);
        let exact = super::super::count_motifs(&g, 3, Policy::Off, 2);
        let tri = catalog::triangle();
        let want = exact.get(&tri).unwrap() as f64;
        if want == 0.0 {
            return;
        }
        let mut errs = Vec::new();
        for samples in [20usize, 480] {
            // average over seeds to smooth variance
            let mut avg = 0.0;
            for seed in 0..8 {
                avg += approx_motifs(&g, 3, samples, seed).get(&tri).unwrap();
            }
            avg /= 8.0;
            errs.push((avg - want).abs() / want);
        }
        assert!(
            errs[1] <= errs[0] + 0.05,
            "more samples should not be much worse: {errs:?}"
        );
        assert!(errs[1] < 0.25, "full-sample mean error too high: {errs:?}");
    }

    #[test]
    fn morphing_estimates_to_edge_induced() {
        // The converted edge-induced estimates must approximate the exact
        // edge-induced counts — morphing applies to estimators.
        let g = erdos_renyi(50, 250, 0xEF);
        let approx = approx_motifs(&g, 4, 250, 3);
        let converted = approx.edge_induced_estimates();
        for (pe, est) in &converted {
            let exact = crate::exec::count_matches(&g, &crate::plan::Plan::compile(pe)) as f64;
            if exact > 100.0 {
                let rel = (est - exact).abs() / exact;
                assert!(rel < 0.5, "{pe:?}: exact {exact} est {est}");
            }
        }
    }

    #[test]
    fn unbiasedness_on_complete_graph() {
        // K6: every anchor sees the same local structure; estimates exact.
        let mut edges = Vec::new();
        for u in 0..6u32 {
            for v in (u + 1)..6 {
                edges.push((u, v));
            }
        }
        let g = crate::graph::GraphBuilder::new().edges(&edges).build("k6");
        let approx = approx_motifs(&g, 4, 15, 9);
        assert_eq!(approx.get(&catalog::clique(4)).unwrap(), 15.0); // C(6,4)
        assert_eq!(approx.get(&catalog::cycle(4).vertex_induced()).unwrap(), 0.0);
    }
}
