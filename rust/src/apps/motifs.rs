//! Motif counting: count all vertex-induced connected patterns of a given
//! size (§2, "Motif Counting").
//!
//! This is the application where pattern morphing shines (§4.4): the motif
//! set contains every superpattern already, so the morphed (edge-induced)
//! alternative set reuses each base pattern for many queries, and counting
//! aggregation makes conversions nearly free — the paper's Figure 5 shows
//! the resulting rewrite for 4-motifs.

use crate::graph::DataGraph;
use crate::morph::{self, Policy};
use crate::pattern::{catalog, Pattern};
use crate::plan::cost::CostParams;
use crate::util::timer::PhaseProfile;

/// Result of a motif-counting run.
#[derive(Debug)]
pub struct MotifCounts {
    /// `(vertex-induced motif, unique-match count)`, deterministic order.
    pub counts: Vec<(Pattern, u64)>,
    /// Matching vs conversion breakdown.
    pub profile: PhaseProfile,
    /// The base patterns actually matched.
    pub base: Vec<Pattern>,
}

impl MotifCounts {
    pub fn total(&self) -> u64 {
        self.counts.iter().map(|(_, c)| c).sum()
    }

    /// Count for a motif given by any isomorphic pattern.
    pub fn get(&self, p: &Pattern) -> Option<u64> {
        let key = p.canonical_key();
        self.counts
            .iter()
            .find(|(q, _)| q.canonical_key() == key)
            .map(|&(_, c)| c)
    }
}

/// Count all motifs with `size` vertices (3 ≤ size ≤ 5 in the paper; 6 is
/// supported but the motif set grows to 112 patterns). The base pattern
/// set is matched with fused co-execution by default.
pub fn count_motifs(
    graph: &DataGraph,
    size: usize,
    policy: Policy,
    threads: usize,
) -> MotifCounts {
    count_motifs_opts(graph, size, policy, morph::ExecOpts::new(threads))
}

/// [`count_motifs`] with explicit execution options (fused on/off).
pub fn count_motifs_opts(
    graph: &DataGraph,
    size: usize,
    policy: Policy,
    opts: morph::ExecOpts,
) -> MotifCounts {
    let motifs = catalog::motifs_vertex_induced(size);
    let mut profile = PhaseProfile::new();

    // one stats instance serves cost-based PMR and fused order selection:
    // reuse the caller's when supplied, else compute once
    let mut opts = opts;
    if policy == Policy::CostBased && opts.stats.is_none() {
        opts.stats = Some(profile.time("stats", || {
            crate::graph::GraphStats::compute(graph, 2000, 0x3077F)
        }));
    }
    let stats_ref = if policy == Policy::CostBased {
        opts.stats.as_ref()
    } else {
        None
    };

    let plan = profile.time("plan", || {
        morph::plan_queries(&motifs, policy, stats_ref, &CostParams::counting())
    });
    let values = morph::execute_opts(graph, &plan, &crate::agg::CountAgg, opts, &mut profile);

    let counts = values
        .into_iter()
        .zip(&motifs)
        .map(|(maps, q)| {
            let aut = crate::pattern::iso::automorphisms(q).len() as i128;
            assert!(maps >= 0 && maps % aut == 0, "bad map count {maps} for {q:?}");
            (q.clone(), (maps / aut) as u64)
        })
        .collect();

    MotifCounts {
        counts,
        profile,
        base: plan.base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::GraphBuilder;
    use crate::pattern::catalog;

    #[test]
    fn motifs3_on_triangle_graph() {
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2), (2, 0)]).build("k3");
        let r = count_motifs(&g, 3, Policy::Off, 1);
        assert_eq!(r.get(&catalog::triangle()), Some(1));
        assert_eq!(r.get(&catalog::path(3).vertex_induced()), Some(0));
    }

    #[test]
    fn motif_policies_agree() {
        let g = erdos_renyi(80, 400, 41);
        let off = count_motifs(&g, 4, Policy::Off, 2);
        let naive = count_motifs(&g, 4, Policy::Naive, 2);
        let cost = count_motifs(&g, 4, Policy::CostBased, 2);
        for ((p, a), ((_, b), (_, c))) in off
            .counts
            .iter()
            .zip(naive.counts.iter().zip(cost.counts.iter()))
        {
            assert_eq!(a, b, "{p:?} naive");
            assert_eq!(a, c, "{p:?} cost");
        }
    }

    #[test]
    fn morphing_shrinks_base_set_work() {
        // with Naive PMR, 4-motifs are counted from edge-induced bases —
        // every base pattern must be edge-induced
        let g = erdos_renyi(50, 200, 42);
        let naive = count_motifs(&g, 4, Policy::Naive, 1);
        assert!(
            naive.base.iter().all(|p| p.is_edge_induced()),
            "bases: {:?}",
            naive.base
        );
        // and there are exactly 6 of them (one per 4-motif topology)
        assert_eq!(naive.base.len(), 6);
    }

    #[test]
    fn fused_toggle_agrees() {
        let g = erdos_renyi(60, 260, 44);
        for policy in [Policy::Off, Policy::Naive] {
            let on = count_motifs_opts(&g, 4, policy, morph::ExecOpts::new(2));
            let off = count_motifs_opts(&g, 4, policy, morph::ExecOpts::new(2).with_fused(false));
            for ((p, a), (_, b)) in on.counts.iter().zip(off.counts.iter()) {
                assert_eq!(a, b, "{policy:?} {p:?}");
            }
        }
    }

    #[test]
    fn motifs5_total_equals_direct() {
        let g = erdos_renyi(40, 140, 43);
        let off = count_motifs(&g, 5, Policy::Off, 2);
        let naive = count_motifs(&g, 5, Policy::Naive, 2);
        assert_eq!(off.total(), naive.total());
        assert_eq!(off.counts.len(), 21);
    }
}
