//! Frequent Subgraph Mining with MNI support (§2, "Frequent Subgraph
//! Mining"): list all labeled edge-induced patterns with `k` edges whose MNI
//! support [6] meets a threshold.
//!
//! Level-wise search: frequent single edges → extend by one edge (to a new
//! labeled vertex or between existing vertices) → prune by the
//! anti-monotone MNI measure → compute supports (optionally through the
//! morphing engine, which is the paper's 3-FSM experiment).

use crate::agg::{aggregate_pattern, MniAgg};
use crate::graph::{DataGraph, GraphStats, Label, VertexId};
use crate::morph::{self, Policy};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::plan::cost::CostParams;
use crate::util::timer::PhaseProfile;
use std::collections::HashMap;

/// FSM configuration.
#[derive(Clone, Debug)]
pub struct FsmConfig {
    /// Target number of pattern edges (paper: 3).
    pub max_edges: usize,
    /// MNI support threshold.
    pub support: u64,
    /// Morphing policy for support computations.
    pub policy: Policy,
    /// Worker threads.
    pub threads: usize,
    /// Fuse each level's base pattern set into one traversal (default on).
    pub fused: bool,
}

/// FSM output.
#[derive(Debug)]
pub struct FsmResult {
    /// Frequent patterns at `max_edges` edges with their supports.
    pub frequent: Vec<(Pattern, u64)>,
    /// All intermediate frequent patterns by edge count (1-based index).
    pub levels: Vec<Vec<(Pattern, u64)>>,
    /// Matching vs aggregation breakdown (Fig. 2).
    pub profile: PhaseProfile,
}

/// Run FSM on a labeled graph.
pub fn fsm(graph: &DataGraph, cfg: &FsmConfig) -> FsmResult {
    assert!(graph.is_labeled(), "FSM requires a labeled graph");
    assert!(cfg.max_edges >= 1);
    let mut profile = PhaseProfile::new();

    // graph statistics are level-invariant: compute once and share across
    // every level's cost-based PMR and fused order selection (the Off
    // policy matches per pattern and needs neither)
    let stats = if cfg.policy == Policy::CostBased || (cfg.fused && cfg.policy != Policy::Off) {
        Some(profile.time("stats", || GraphStats::compute(graph, 2000, 0xF53)))
    } else {
        None
    };

    // ---- level 1: frequent single edges -------------------------------
    let mut edge_domains: HashMap<(Label, Label), (HashMap<VertexId, ()>, HashMap<VertexId, ()>)> =
        HashMap::new();
    profile.time("match", || {
        for v in 0..graph.num_vertices() as VertexId {
            for &u in graph.neighbors(v) {
                let (a, b) = (graph.label(v), graph.label(u));
                let key = if a <= b { (a, b) } else { (b, a) };
                let e = edge_domains.entry(key).or_default();
                let (x, y) = if a <= b { (v, u) } else { (u, v) };
                e.0.insert(x, ());
                e.1.insert(y, ());
            }
        }
    });
    let mut level: Vec<(Pattern, u64)> = edge_domains
        .into_iter()
        .map(|((a, b), (da, db))| {
            let p = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[a, b]);
            (p, da.len().min(db.len()) as u64)
        })
        .filter(|&(_, s)| s >= cfg.support)
        .collect();
    level.sort_by_key(|(p, _)| p.canonical_key());
    let mut levels = vec![level];

    // ---- levels 2..k: extend + support --------------------------------
    let num_labels = graph.num_labels();
    for _edge_count in 2..=cfg.max_edges {
        let prev = levels.last().unwrap();
        if prev.is_empty() {
            levels.push(Vec::new());
            continue;
        }
        // candidate generation
        let mut cands: HashMap<CanonKey, Pattern> = HashMap::new();
        profile.time("extend", || {
            for (p, _) in prev {
                for q in extensions(p, num_labels) {
                    cands.entry(q.canonical_key()).or_insert(q);
                }
            }
        });
        let mut cand_list: Vec<Pattern> = cands.into_values().collect();
        cand_list.sort_by_key(|p| p.canonical_key());

        // support computation (optionally morphed)
        let supports = compute_supports(graph, &cand_list, cfg, stats.as_ref(), &mut profile);
        let mut next: Vec<(Pattern, u64)> = cand_list
            .into_iter()
            .zip(supports)
            .filter(|&(_, s)| s >= cfg.support)
            .collect();
        next.sort_by_key(|(p, _)| p.canonical_key());
        levels.push(next);
    }

    FsmResult {
        frequent: levels.last().unwrap().clone(),
        levels,
        profile,
    }
}

/// One-edge extensions of an edge-induced labeled pattern: an edge between
/// two existing non-adjacent vertices, or an edge to a fresh vertex with
/// every possible label. Connected by construction.
fn extensions(p: &Pattern, num_labels: u32) -> Vec<Pattern> {
    let n = p.num_vertices();
    let mut out = Vec::new();
    // close an open pair
    for (u, v) in p.open_pairs() {
        let mut q = p.clone();
        q.add_edge(u, v);
        out.push(q);
    }
    // grow by a labeled vertex
    if n < crate::pattern::MAX_PATTERN_VERTICES {
        let labels = p.labels_vec().expect("FSM patterns are labeled");
        for anchor in 0..n {
            for lab in 0..num_labels {
                let mut nl = labels.clone();
                nl.push(lab);
                let mut q = Pattern::from_edges(n + 1, &p.edges()).with_labels(&nl);
                q.add_edge(anchor, n);
                out.push(q);
            }
        }
    }
    out
}

/// MNI supports for a candidate list, through the morphing engine.
/// `stats` are the caller's level-invariant graph statistics (shared by
/// cost-based PMR and fused order selection).
fn compute_supports(
    graph: &DataGraph,
    cands: &[Pattern],
    cfg: &FsmConfig,
    stats: Option<&GraphStats>,
    profile: &mut PhaseProfile,
) -> Vec<u64> {
    if cands.is_empty() {
        return Vec::new();
    }
    match cfg.policy {
        Policy::Off => cands
            .iter()
            .map(|p| {
                let agg = MniAgg {
                    n: p.num_vertices(),
                };
                let t = profile.time("match", || {
                    aggregate_pattern(graph, p, &agg, cfg.threads)
                });
                profile.time("aggregate", || t.support())
            })
            .collect(),
        Policy::Naive | Policy::CostBased => {
            // FSM patterns can have heterogeneous sizes in one level (3 edges
            // on 3 or 4 vertices); morph expressions stay within one size, so
            // group by vertex count and run the engine per group.
            let mut result = vec![0u64; cands.len()];
            let mut by_size: HashMap<usize, Vec<usize>> = HashMap::new();
            for (i, p) in cands.iter().enumerate() {
                by_size.entry(p.num_vertices()).or_default().push(i);
            }
            let stats_ref = if cfg.policy == Policy::CostBased {
                stats
            } else {
                None
            };
            for (size, idxs) in by_size {
                let queries: Vec<Pattern> = idxs.iter().map(|&i| cands[i].clone()).collect();
                let plan = profile.time("plan", || {
                    morph::plan_queries(&queries, cfg.policy, stats_ref, &CostParams::mni(size))
                });
                let agg = MniAgg { n: size };
                let mut opts = morph::ExecOpts::new(cfg.threads).with_fused(cfg.fused);
                if let Some(s) = stats {
                    opts = opts.with_stats(s.clone());
                }
                let tables = morph::execute_opts(graph, &plan, &agg, opts, profile);
                for (t, &i) in tables.iter().zip(&idxs) {
                    t.assert_consistent();
                    result[i] = t.support();
                }
            }
            result
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{assign_labels, erdos_renyi};
    use crate::graph::GraphBuilder;

    fn labeled_graph(seed: u64) -> DataGraph {
        assign_labels(erdos_renyi(60, 220, seed), 3, 1.3, seed + 1)
    }

    fn cfg(support: u64, policy: Policy) -> FsmConfig {
        FsmConfig {
            max_edges: 3,
            support,
            policy,
            threads: 2,
            fused: true,
        }
    }

    #[test]
    fn fsm_level1_counts_edges() {
        // two labels, star: center 0 label 0, leaves label 1
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3)])
            .labels(vec![0, 1, 1, 1])
            .build("s");
        let r = fsm(
            &g,
            &FsmConfig {
                max_edges: 1,
                support: 1,
                policy: Policy::Off,
                threads: 1,
                fused: true,
            },
        );
        assert_eq!(r.frequent.len(), 1);
        assert_eq!(r.frequent[0].1, 1, "MNI support = min(|{{0}}|, |leaves|)");
    }

    #[test]
    fn fsm_policies_agree() {
        let g = labeled_graph(61);
        let off = fsm(&g, &cfg(3, Policy::Off));
        let naive = fsm(&g, &cfg(3, Policy::Naive));
        let cost = fsm(&g, &cfg(3, Policy::CostBased));
        let norm = |r: &FsmResult| {
            let mut v: Vec<(CanonKey, u64)> = r
                .frequent
                .iter()
                .map(|(p, s)| (p.canonical_key(), *s))
                .collect();
            v.sort();
            v
        };
        assert_eq!(norm(&off), norm(&naive));
        assert_eq!(norm(&off), norm(&cost));
        assert!(!off.frequent.is_empty(), "threshold too high for the test graph");
    }

    #[test]
    fn fsm_fused_toggle_agrees() {
        let g = labeled_graph(63);
        let on = cfg(3, Policy::Naive);
        let mut off = cfg(3, Policy::Naive);
        off.fused = false;
        let ra = fsm(&g, &on);
        let rb = fsm(&g, &off);
        let norm = |r: &FsmResult| {
            let mut v: Vec<(CanonKey, u64)> = r
                .frequent
                .iter()
                .map(|(p, s)| (p.canonical_key(), *s))
                .collect();
            v.sort();
            v
        };
        assert_eq!(norm(&ra), norm(&rb));
    }

    #[test]
    fn fsm_antimonotone_levels_shrink_with_support() {
        let g = labeled_graph(62);
        let lo = fsm(&g, &cfg(2, Policy::Off));
        let hi = fsm(&g, &cfg(8, Policy::Off));
        assert!(hi.frequent.len() <= lo.frequent.len());
    }

    #[test]
    fn fsm_supports_are_mni() {
        // path graph 0-1-2 labels a,b,a: pattern (a-b) support = min(2,1)=1
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2)])
            .labels(vec![0, 1, 0])
            .build("p");
        let r = fsm(
            &g,
            &FsmConfig {
                max_edges: 1,
                support: 1,
                policy: Policy::Off,
                threads: 1,
                fused: true,
            },
        );
        assert_eq!(r.frequent[0].1, 1);
    }

    #[test]
    fn fsm_triangle_pattern_found() {
        // build a graph with many mono-label triangles
        let mut edges = Vec::new();
        for t in 0..5u32 {
            let b = t * 3;
            edges.extend([(b, b + 1), (b + 1, b + 2), (b + 2, b)]);
        }
        let g = GraphBuilder::new()
            .edges(&edges)
            .labels(vec![0; 15])
            .build("tris");
        let r = fsm(
            &g,
            &FsmConfig {
                max_edges: 3,
                support: 5,
                policy: Policy::Off,
                threads: 1,
                fused: true,
            },
        );
        // frequent 3-edge patterns must include the mono-label triangle
        let tri = Pattern::from_edges(3, &[(0, 1), (1, 2), (2, 0)]).with_labels(&[0, 0, 0]);
        assert!(
            r.frequent
                .iter()
                .any(|(p, _)| p.canonical_key() == tri.canonical_key()),
            "triangle not found among {:?}",
            r.frequent
        );
    }
}
