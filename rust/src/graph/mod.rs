//! Data-graph substrate: CSR storage, builders, IO, synthetic generators and
//! the statistics that feed the morphing cost model.
//!
//! The paper evaluates on Mico, Patents, YouTube and Orkut. Those exact
//! datasets are not available in this environment, so [`generators`]
//! synthesizes stand-ins with matched degree skew, density and label
//! cardinality (see DESIGN.md §5). All mining code is dataset-agnostic.

pub mod bitmap;
mod builder;
mod csr;
pub mod dynamic;
pub mod generators;
pub mod io;
pub mod relabel;
pub mod stats;

pub use builder::GraphBuilder;
pub use csr::{DataGraph, GraphFingerprint};
pub use dynamic::DynGraph;
pub use relabel::Relabeling;
pub use stats::GraphStats;

/// Vertex identifier in a data graph.
pub type VertexId = u32;

/// Vertex label (dense small integers; `0..num_labels`).
pub type Label = u32;
