//! Graph statistics feeding the morphing cost model (paper §4.1, factor 3:
//! "the details of the data graph", including degree distribution,
//! connectivity and label distributions).

use super::{DataGraph, VertexId};

/// Summary statistics of a data graph.
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub max_degree: usize,
    pub avg_degree: f64,
    /// Σ d(v)
    pub deg_sum: f64,
    /// Σ d(v)²
    pub deg_sq_sum: f64,
    /// Σ C(d(v), 2) — the number of wedges (2-paths).
    pub wedges: f64,
    /// Edge density 2m / n(n-1).
    pub density: f64,
    /// Probability that a random vertex pair is adjacent (== density).
    pub edge_prob: f64,
    /// Expected size of the intersection of two random adjacency lists.
    pub avg_intersection: f64,
    /// Sampled global clustering coefficient (triangles / wedges).
    pub clustering: f64,
    /// Per-label vertex frequency (empty for unlabeled graphs).
    pub label_freq: Vec<f64>,
    /// Number of hub vertices carrying dense bitmap rows in the hybrid
    /// adjacency (0 when the graph has none).
    pub hub_count: usize,
    /// Probability that a random edge endpoint is a hub — i.e. the chance
    /// that a set-op operand at depth ≥ 1 is served by an O(1)-membership
    /// bitmap row instead of a sorted-list merge. Feeds the cost model's
    /// hub discount ([`crate::plan::cost`]).
    pub hub_edge_fraction: f64,
}

impl GraphStats {
    /// Compute stats; triangle/clustering estimated by sampling `samples`
    /// wedges (exact enumeration would defeat the purpose of a cost model).
    pub fn compute(g: &DataGraph, samples: usize, seed: u64) -> GraphStats {
        let n = g.num_vertices();
        let m = g.num_edges();
        let mut deg_sum = 0f64;
        let mut deg_sq = 0f64;
        let mut wedges = 0f64;
        let mut max_degree = 0usize;
        for v in 0..n as VertexId {
            let d = g.degree(v) as f64;
            deg_sum += d;
            deg_sq += d * d;
            wedges += d * (d - 1.0) / 2.0;
            max_degree = max_degree.max(g.degree(v));
        }
        let density = if n > 1 {
            2.0 * m as f64 / (n as f64 * (n as f64 - 1.0))
        } else {
            0.0
        };
        // E[|N(u) ∩ N(v)|] for random u,v ≈ (Σd)²/(n²) * 1/n * ... use the
        // configuration-model estimate: Σ d(w)(d(w)-1)/ ... simplified:
        // each w is a common neighbor with prob (d_w/2m)² per incident
        // edge pair; expected common neighbors = Σ d_w (d_w -1) / n² * ...
        // We use wedges * 2 / n² which is exact for the config model.
        let avg_intersection = if n > 0 {
            2.0 * wedges / (n as f64 * n as f64)
        } else {
            0.0
        };

        // sampled clustering: pick random wedges, check closure
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut closed = 0usize;
        let mut tried = 0usize;
        if m > 0 {
            for _ in 0..samples {
                let v = rng.below_usize(n) as VertexId;
                let d = g.degree(v);
                if d < 2 {
                    continue;
                }
                let ns = g.neighbors(v);
                let a = ns[rng.below_usize(d)];
                let b = ns[rng.below_usize(d)];
                if a == b {
                    continue;
                }
                tried += 1;
                if g.has_edge(a, b) {
                    closed += 1;
                }
            }
        }
        let clustering = if tried > 0 {
            closed as f64 / tried as f64
        } else {
            0.0
        };

        let hub_count = g.hub_count();
        let hub_deg_sum: f64 = g.hub_vertices().iter().map(|&h| g.degree(h) as f64).sum();
        let hub_edge_fraction = if deg_sum > 0.0 {
            hub_deg_sum / deg_sum
        } else {
            0.0
        };

        let label_freq = if g.is_labeled() {
            let mut hist = vec![0f64; g.num_labels() as usize];
            for v in 0..n as VertexId {
                hist[g.label(v) as usize] += 1.0;
            }
            hist.iter_mut().for_each(|c| *c /= n as f64);
            hist
        } else {
            Vec::new()
        };

        GraphStats {
            num_vertices: n,
            num_edges: m,
            max_degree,
            avg_degree: if n > 0 { deg_sum / n as f64 } else { 0.0 },
            deg_sum,
            deg_sq_sum: deg_sq,
            wedges,
            density,
            edge_prob: density,
            avg_intersection,
            clustering,
            label_freq,
            hub_count,
            hub_edge_fraction,
        }
    }

    /// Stats of a nominal mid-size, mildly skewed mining graph (≈100k
    /// vertices, avg degree 20, size-biased degree 80, clustering 0.1).
    ///
    /// Used when a *relative* ranking is needed but no data graph is in
    /// scope — e.g. the fused set-planner scoring matching orders
    /// policy-independently. Only ratios between plan costs matter, so a
    /// plausible fixed shape is enough.
    pub fn synthetic() -> GraphStats {
        let n = 100_000.0;
        let m = 1_000_000.0;
        let deg_sum = 2.0 * m;
        let wedges = 4.0e7;
        let density = 2.0 * m / (n * (n - 1.0));
        GraphStats {
            num_vertices: n as usize,
            num_edges: m as usize,
            max_degree: 1000,
            avg_degree: deg_sum / n,
            deg_sum,
            deg_sq_sum: 80.0 * deg_sum, // size-biased degree Σd²/Σd = 80
            wedges,
            density,
            edge_prob: density,
            avg_intersection: 2.0 * wedges / (n * n),
            clustering: 0.1,
            label_freq: Vec::new(),
            // the synthetic shape is hub-free: no discount, so rankings
            // computed without a real graph stay conservative
            hub_count: 0,
            hub_edge_fraction: 0.0,
        }
    }

    /// Frequency of `label` (1.0 for unlabeled graphs — no selectivity).
    pub fn label_prob(&self, label: u32) -> f64 {
        if self.label_freq.is_empty() {
            1.0
        } else {
            self.label_freq.get(label as usize).copied().unwrap_or(0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::GraphBuilder;

    #[test]
    fn triangle_stats() {
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2), (2, 0)]).build("k3");
        let s = GraphStats::compute(&g, 1000, 1);
        assert_eq!(s.num_vertices, 3);
        assert_eq!(s.num_edges, 3);
        assert_eq!(s.max_degree, 2);
        assert!((s.avg_degree - 2.0).abs() < 1e-9);
        assert!((s.wedges - 3.0).abs() < 1e-9);
        assert!((s.density - 1.0).abs() < 1e-9);
        assert!((s.clustering - 1.0).abs() < 1e-9, "triangle closes all wedges");
    }

    #[test]
    fn er_clustering_low() {
        let g = erdos_renyi(500, 1500, 7);
        let s = GraphStats::compute(&g, 2000, 2);
        assert!(s.clustering < 0.1, "ER graphs have ~p clustering, got {}", s.clustering);
    }

    #[test]
    fn label_probs_sum_to_one() {
        let g = crate::graph::generators::assign_labels(erdos_renyi(300, 600, 3), 10, 1.5, 4);
        let s = GraphStats::compute(&g, 100, 5);
        let sum: f64 = s.label_freq.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!((s.label_prob(0) - s.label_freq[0]).abs() < 1e-12);
    }

    #[test]
    fn hub_fraction_reflects_hybrid_index() {
        // star: the center is a hub holding half of all edge endpoints
        let edges: Vec<(u32, u32)> = (1..=100).map(|v| (0, v)).collect();
        let g = GraphBuilder::new().edges(&edges).build("star");
        let s = GraphStats::compute(&g, 100, 3);
        assert_eq!(s.hub_count, 1);
        assert!((s.hub_edge_fraction - 0.5).abs() < 1e-9, "{}", s.hub_edge_fraction);
        // stripped index reports no hub coverage
        let s2 = GraphStats::compute(&g.without_hub_bitmaps(), 100, 3);
        assert_eq!(s2.hub_count, 0);
        assert_eq!(s2.hub_edge_fraction, 0.0);
    }

    #[test]
    fn unlabeled_label_prob_is_one() {
        let g = erdos_renyi(50, 100, 9);
        let s = GraphStats::compute(&g, 10, 1);
        assert_eq!(s.label_prob(3), 1.0);
    }
}
