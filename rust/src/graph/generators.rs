//! Synthetic graph generators and the dataset registry.
//!
//! The paper evaluates on four real graphs (Table 2): Mico (100K/1.1M,
//! 29 labels), Patents (3.7M/16M, 37 labels), YouTube (6.9M/44M, 38 labels)
//! and Orkut (3M/117M, unlabeled). Those datasets are not redistributable /
//! available offline, so we synthesize structurally matched stand-ins at a
//! reduced scale (documented in DESIGN.md §5): the *relative* costs of
//! matching different patterns — which drive every morphing decision — are
//! governed by degree skew, density and label selectivity, all of which the
//! generators control.

use super::{GraphBuilder, Label, VertexId};
use crate::graph::DataGraph;
use crate::util::rng::Rng;

/// Erdős–Rényi G(n, m): `m` distinct uniform edges.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> DataGraph {
    let mut rng = Rng::new(seed);
    let mut seen = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    let max_edges = n * (n - 1) / 2;
    let m = m.min(max_edges);
    while edges.len() < m {
        let u = rng.below_usize(n) as VertexId;
        let v = rng.below_usize(n) as VertexId;
        if u == v {
            continue;
        }
        let key = if u < v { (u, v) } else { (v, u) };
        if seen.insert(key) {
            edges.push(key);
        }
    }
    GraphBuilder::new()
        .edges(&edges)
        .num_vertices(n)
        .build(&format!("er-{n}-{m}"))
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `k` existing vertices chosen proportionally to degree. Produces the
/// heavy-tailed degree distributions of social / citation networks.
pub fn barabasi_albert(n: usize, k: usize, seed: u64) -> DataGraph {
    assert!(n > k && k >= 1);
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * k);
    // endpoint pool: sampling uniformly from it == degree-proportional
    let mut pool: Vec<VertexId> = Vec::with_capacity(2 * n * k);
    // seed clique on k+1 vertices
    for u in 0..=k {
        for v in (u + 1)..=k {
            edges.push((u as VertexId, v as VertexId));
            pool.push(u as VertexId);
            pool.push(v as VertexId);
        }
    }
    for v in (k + 1)..n {
        // NOTE: collect + sort instead of iterating a HashSet — HashSet
        // iteration order is randomized per process, which would make the
        // preferential-attachment pool (and hence the whole graph)
        // non-reproducible across runs.
        let mut targets = std::collections::HashSet::with_capacity(k);
        let mut guard = 0;
        while targets.len() < k && guard < 50 * k {
            let t = pool[rng.below_usize(pool.len())];
            targets.insert(t);
            guard += 1;
        }
        let mut targets: Vec<VertexId> = targets.into_iter().collect();
        targets.sort_unstable();
        for &t in &targets {
            edges.push((v as VertexId, t));
            pool.push(v as VertexId);
            pool.push(t);
        }
    }
    GraphBuilder::new()
        .edges(&edges)
        .num_vertices(n)
        .degree_ordered(true)
        .build(&format!("ba-{n}-{k}"))
}

/// RMAT / Kronecker-style generator with quadrant probabilities
/// `(a, b, c, d)`. Produces power-law graphs with community-ish structure
/// (used for the Orkut stand-in: denser, very heavy tail).
pub fn rmat(scale: u32, m: usize, probs: (f64, f64, f64, f64), seed: u64) -> DataGraph {
    let n = 1usize << scale;
    let (a, b, c, _d) = probs;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m);
    let mut seen = std::collections::HashSet::with_capacity(m * 2);
    let mut attempts = 0usize;
    while edges.len() < m && attempts < m * 20 {
        attempts += 1;
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        if u == v {
            continue;
        }
        let key = if u < v {
            (u as VertexId, v as VertexId)
        } else {
            (v as VertexId, u as VertexId)
        };
        if seen.insert(key) {
            edges.push(key);
        }
    }
    GraphBuilder::new()
        .edges(&edges)
        .num_vertices(n)
        .degree_ordered(true)
        .build(&format!("rmat-{scale}-{m}"))
}

/// Assign labels with a power-law distribution over `num_labels` (real
/// datasets have highly skewed label frequencies, which is what makes FSM
/// supports vary; exponent ~1.5 matches Mico/Patents-like skew).
pub fn assign_labels(g: DataGraph, num_labels: u32, alpha: f64, seed: u64) -> DataGraph {
    let mut rng = Rng::new(seed);
    let labels: Vec<Label> = (0..g.num_vertices())
        .map(|_| rng.powerlaw(num_labels as usize, alpha) as Label)
        .collect();
    let name = g.name().to_string();
    // rebuild with labels (cheap relative to generation)
    let mut edges = Vec::with_capacity(g.num_edges());
    for v in 0..g.num_vertices() as VertexId {
        for &u in g.neighbors(v) {
            if v < u {
                edges.push((v, u));
            }
        }
    }
    GraphBuilder::new()
        .edges(&edges)
        .num_vertices(g.num_vertices())
        .labels(labels)
        .build(&name)
}

/// Scale of the synthetic dataset stand-ins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// For unit/property tests: hundreds of vertices.
    Tiny,
    /// Default benchmark scale: finishes the full Table-3 grid in minutes.
    Small,
    /// Closer to paper proportions (still reduced); minutes-to-hours.
    Medium,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "tiny" => Some(Scale::Tiny),
            "small" => Some(Scale::Small),
            "medium" => Some(Scale::Medium),
            _ => None,
        }
    }

    fn factor(self) -> f64 {
        match self {
            Scale::Tiny => 0.02,
            Scale::Small => 0.2,
            Scale::Medium => 1.0,
        }
    }
}

/// Named dataset stand-ins mirroring Table 2 of the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// Mico-like: co-authorship, dense-ish, 29 labels.
    MicoSim,
    /// Patents-like: citation network, sparse, 37 labels.
    PatentsSim,
    /// YouTube-like: heavy-tailed, 38 labels.
    YoutubeSim,
    /// Orkut-like: social network, dense, heavy tail, unlabeled.
    OrkutSim,
}

impl Dataset {
    pub fn all() -> [Dataset; 4] {
        [
            Dataset::MicoSim,
            Dataset::PatentsSim,
            Dataset::YoutubeSim,
            Dataset::OrkutSim,
        ]
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        match s {
            "mico" | "mico-sim" | "MI" => Some(Dataset::MicoSim),
            "patents" | "patents-sim" | "PA" => Some(Dataset::PatentsSim),
            "youtube" | "youtube-sim" | "YT" => Some(Dataset::YoutubeSim),
            "orkut" | "orkut-sim" | "OK" => Some(Dataset::OrkutSim),
            _ => None,
        }
    }

    /// Short code used in the paper's tables.
    pub fn code(self) -> &'static str {
        match self {
            Dataset::MicoSim => "MI",
            Dataset::PatentsSim => "PA",
            Dataset::YoutubeSim => "YT",
            Dataset::OrkutSim => "OK",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dataset::MicoSim => "mico-sim",
            Dataset::PatentsSim => "patents-sim",
            Dataset::YoutubeSim => "youtube-sim",
            Dataset::OrkutSim => "orkut-sim",
        }
    }

    /// Number of labels in the stand-in (0 = unlabeled), mirroring Table 2.
    pub fn num_labels(self) -> u32 {
        match self {
            Dataset::MicoSim => 29,
            Dataset::PatentsSim => 37,
            Dataset::YoutubeSim => 38,
            Dataset::OrkutSim => 0,
        }
    }

    /// Generate the stand-in graph at `scale`.
    ///
    /// Proportions follow Table 2: Mico dense-ish (avg deg 22), Patents
    /// sparse (avg 10), YouTube mid (avg 12, biggest vertex count), Orkut
    /// densest (avg deg scaled down from 76 to keep 4-MC tractable on this
    /// testbed — relative ordering across datasets is preserved).
    pub fn generate(self, scale: Scale) -> DataGraph {
        let f = scale.factor();
        let g = match self {
            Dataset::MicoSim => {
                let n = (30_000.0 * f) as usize;
                barabasi_albert(n.max(100), 11, 0x31C0)
            }
            Dataset::PatentsSim => {
                let n = (120_000.0 * f) as usize;
                barabasi_albert(n.max(100), 5, 0x9A7E)
            }
            Dataset::YoutubeSim => {
                let n = (200_000.0 * f) as usize;
                barabasi_albert(n.max(100), 6, 0x707B)
            }
            Dataset::OrkutSim => {
                let n = (60_000.0 * f) as usize;
                barabasi_albert(n.max(100), 19, 0x0BC7)
            }
        };
        let g = match self {
            Dataset::MicoSim => assign_labels(g, 29, 1.5, 101),
            Dataset::PatentsSim => assign_labels(g, 37, 1.4, 102),
            Dataset::YoutubeSim => assign_labels(g, 38, 1.6, 103),
            Dataset::OrkutSim => g,
        };
        // rebuild keeps the builder's name; rename to the dataset's
        let mut edges = Vec::with_capacity(g.num_edges());
        for v in 0..g.num_vertices() as VertexId {
            for &u in g.neighbors(v) {
                if v < u {
                    edges.push((v, u));
                }
            }
        }
        let mut b = GraphBuilder::new().edges(&edges).num_vertices(g.num_vertices());
        if g.is_labeled() {
            b = b.labels((0..g.num_vertices()).map(|v| g.label(v as VertexId)).collect());
        }
        b.build(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_has_requested_edges() {
        let g = erdos_renyi(100, 300, 1);
        assert_eq!(g.num_vertices(), 100);
        assert_eq!(g.num_edges(), 300);
        assert!(g.check_invariants());
    }

    #[test]
    fn er_caps_at_complete_graph() {
        let g = erdos_renyi(5, 100, 2);
        assert_eq!(g.num_edges(), 10);
    }

    #[test]
    fn ba_heavy_tail() {
        let g = barabasi_albert(2000, 4, 3);
        assert!(g.check_invariants());
        // heavy tail: max degree far above average
        let avg = 2.0 * g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(
            g.max_degree() as f64 > 4.0 * avg,
            "max {} avg {avg}",
            g.max_degree()
        );
        // degree-ordered rename: vertex 0 is the hub
        assert_eq!(g.degree(0), g.max_degree());
    }

    #[test]
    fn rmat_generates() {
        let g = rmat(10, 3000, (0.57, 0.19, 0.19, 0.05), 4);
        assert!(g.check_invariants());
        assert!(g.num_edges() > 2500);
    }

    #[test]
    fn labels_distribution_skewed() {
        let g = assign_labels(erdos_renyi(1000, 2000, 5), 20, 1.5, 6);
        assert!(g.is_labeled());
        let mut hist = vec![0usize; 20];
        for v in 0..1000 {
            hist[g.label(v) as usize] += 1;
        }
        assert!(hist[0] > hist[10], "label 0 should be most frequent");
    }

    #[test]
    fn dataset_registry_tiny() {
        for d in Dataset::all() {
            let g = d.generate(Scale::Tiny);
            assert!(g.num_vertices() >= 100, "{}", d.name());
            assert!(g.check_invariants(), "{}", d.name());
            assert_eq!(g.is_labeled(), d.num_labels() > 0);
            assert_eq!(g.name(), d.name());
        }
    }

    #[test]
    fn dataset_parse_codes() {
        assert_eq!(Dataset::parse("MI"), Some(Dataset::MicoSim));
        assert_eq!(Dataset::parse("orkut"), Some(Dataset::OrkutSim));
        assert_eq!(Dataset::parse("nope"), None);
    }
}
