//! Degree-ordered vertex relabeling.
//!
//! Renumbering vertices by descending degree concentrates the heavy
//! adjacency lists at small IDs, which (a) makes ID-order symmetry-breaking
//! windows (`m[j] < m[i]` style constraints) align with adjacency-list
//! *prefixes*, so the exploration kernel can trim candidates with a single
//! `partition_point` before any merge work, and (b) puts every hub vertex
//! in a contiguous ID range, which is what makes the hub-bitmap rows of
//! [`super::bitmap`] cheap to index.
//!
//! The relabeling is recorded as an explicit old↔new map carried by the
//! [`super::DataGraph`], so user-facing outputs (enumeration, IO) can keep
//! reporting the original IDs while the matching engine works entirely in
//! the relabeled space.

use super::VertexId;

/// A bijective vertex renaming with both directions materialized.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Relabeling {
    /// `to_new[old] = new`.
    pub to_new: Vec<VertexId>,
    /// `to_old[new] = old`.
    pub to_old: Vec<VertexId>,
}

impl Relabeling {
    /// Identity relabeling over `n` vertices.
    pub fn identity(n: usize) -> Relabeling {
        let ids: Vec<VertexId> = (0..n as VertexId).collect();
        Relabeling {
            to_new: ids.clone(),
            to_old: ids,
        }
    }

    /// Relabeling that assigns ID 0 to the highest-degree vertex, ID 1 to
    /// the next, and so on. Ties break by ascending original ID, so the
    /// result is deterministic.
    pub fn degree_descending(degrees: &[usize]) -> Relabeling {
        let n = degrees.len();
        let mut to_old: Vec<VertexId> = (0..n as VertexId).collect();
        to_old.sort_by_key(|&v| (std::cmp::Reverse(degrees[v as usize]), v));
        let mut to_new = vec![0 as VertexId; n];
        for (new_id, &old_id) in to_old.iter().enumerate() {
            to_new[old_id as usize] = new_id as VertexId;
        }
        Relabeling { to_new, to_old }
    }

    /// Number of vertices covered.
    pub fn len(&self) -> usize {
        self.to_new.len()
    }

    /// Whether the map is empty (zero vertices).
    pub fn is_empty(&self) -> bool {
        self.to_new.is_empty()
    }

    /// New ID of original vertex `old`.
    #[inline]
    pub fn new_id(&self, old: VertexId) -> VertexId {
        self.to_new[old as usize]
    }

    /// Original ID of relabeled vertex `new`.
    #[inline]
    pub fn old_id(&self, new: VertexId) -> VertexId {
        self.to_old[new as usize]
    }

    /// Whether this is the identity map.
    pub fn is_identity(&self) -> bool {
        self.to_new
            .iter()
            .enumerate()
            .all(|(i, &v)| v == i as VertexId)
    }

    /// Check that both directions are mutually inverse permutations.
    pub fn check(&self) -> bool {
        let n = self.len();
        if self.to_old.len() != n {
            return false;
        }
        self.to_old.iter().enumerate().all(|(new, &old)| {
            (old as usize) < n && self.to_new[old as usize] == new as VertexId
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_descending_orders_hubs_first() {
        // degrees: vertex 2 is the hub, then 0, then 1 and 3 tie
        let r = Relabeling::degree_descending(&[2, 1, 5, 1]);
        assert_eq!(r.new_id(2), 0);
        assert_eq!(r.new_id(0), 1);
        // tie between 1 and 3 breaks by original id
        assert_eq!(r.new_id(1), 2);
        assert_eq!(r.new_id(3), 3);
        assert_eq!(r.old_id(0), 2);
        assert!(r.check());
        assert!(!r.is_identity());
    }

    #[test]
    fn identity_roundtrip() {
        let r = Relabeling::identity(5);
        assert!(r.is_identity());
        assert!(r.check());
        assert_eq!(r.len(), 5);
        for v in 0..5u32 {
            assert_eq!(r.new_id(v), v);
            assert_eq!(r.old_id(v), v);
        }
    }

    #[test]
    fn check_rejects_corrupt_maps() {
        let mut r = Relabeling::identity(3);
        r.to_new[0] = 2; // no longer a bijection inverse of to_old
        assert!(!r.check());
    }
}
