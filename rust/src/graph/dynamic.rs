//! Mutable adjacency graph for incremental mining (paper §1, "Other
//! Applications": incremental mining / mining on graph streams).
//!
//! [`DynGraph`] supports edge insertion/deletion with sorted adjacency kept
//! incrementally, and converts to/from the immutable CSR [`DataGraph`] used
//! by the batch matcher.

use super::csr::fingerprint_of;
use super::{DataGraph, GraphBuilder, GraphFingerprint, Label, VertexId};

/// A mutable undirected simple graph.
#[derive(Clone, Debug, Default)]
pub struct DynGraph {
    adj: Vec<Vec<VertexId>>,
    labels: Option<Vec<Label>>,
    num_edges: usize,
    /// Epoch counter: bumped by every **applied** mutation (no-op
    /// insert/remove of an existing/absent edge leaves it unchanged).
    /// Consumers that cache derived results — the result store in
    /// [`crate::service`] — key them by this value so a mutated graph can
    /// never silently serve stale answers.
    version: u64,
}

impl DynGraph {
    pub fn new(n: usize) -> DynGraph {
        DynGraph {
            adj: vec![Vec::new(); n],
            labels: None,
            num_edges: 0,
            version: 0,
        }
    }

    /// Import from CSR.
    pub fn from_data_graph(g: &DataGraph) -> DynGraph {
        let n = g.num_vertices();
        DynGraph {
            adj: (0..n as VertexId).map(|v| g.neighbors(v).to_vec()).collect(),
            labels: g
                .is_labeled()
                .then(|| (0..n as VertexId).map(|v| g.label(v)).collect()),
            num_edges: g.num_edges(),
            version: 0,
        }
    }

    /// Graph epoch: the number of applied mutations since construction.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Content fingerprint of the **current** adjacency state. Hashes the
    /// same stream as [`DataGraph::fingerprint`], so it always equals the
    /// fingerprint of [`DynGraph::to_data_graph`]'s output — callers can
    /// identify the graph a snapshot *would* have without building one.
    /// Unlike [`DynGraph::version`], which restarts at zero every process,
    /// this is stable across restarts: the persistence layer keys durable
    /// store artifacts by it.
    pub fn fingerprint(&self) -> GraphFingerprint {
        fingerprint_of(
            self.adj.len(),
            self.num_edges,
            self.adj.iter().map(|ns| ns.as_slice()),
            self.labels.as_deref(),
        )
    }

    /// Export to CSR (for the batch matcher).
    pub fn to_data_graph(&self, name: &str) -> DataGraph {
        let mut edges = Vec::with_capacity(self.num_edges);
        for (v, ns) in self.adj.iter().enumerate() {
            for &u in ns {
                if (v as VertexId) < u {
                    edges.push((v as VertexId, u));
                }
            }
        }
        let mut b = GraphBuilder::new().edges(&edges).num_vertices(self.adj.len());
        if let Some(l) = &self.labels {
            b = b.labels(l.clone());
        }
        b.build(name)
    }

    pub fn num_vertices(&self) -> usize {
        self.adj.len()
    }

    pub fn num_edges(&self) -> usize {
        self.num_edges
    }

    pub fn degree(&self, v: VertexId) -> usize {
        self.adj[v as usize].len()
    }

    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.adj[v as usize]
    }

    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        self.adj[a as usize].binary_search(&b).is_ok()
    }

    /// Insert edge; returns false if it already existed (no-op).
    pub fn insert_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        assert_ne!(u, v, "self loops not allowed");
        let max = u.max(v) as usize;
        if max >= self.adj.len() {
            self.adj.resize(max + 1, Vec::new());
            if let Some(l) = &mut self.labels {
                l.resize(max + 1, 0);
            }
        }
        match self.adj[u as usize].binary_search(&v) {
            Ok(_) => false,
            Err(i) => {
                self.adj[u as usize].insert(i, v);
                let j = self.adj[v as usize].binary_search(&u).unwrap_err();
                self.adj[v as usize].insert(j, u);
                self.num_edges += 1;
                self.version += 1;
                true
            }
        }
    }

    /// Remove edge; returns false if absent.
    pub fn remove_edge(&mut self, u: VertexId, v: VertexId) -> bool {
        match self.adj[u as usize].binary_search(&v) {
            Err(_) => false,
            Ok(i) => {
                self.adj[u as usize].remove(i);
                let j = self.adj[v as usize].binary_search(&u).unwrap();
                self.adj[v as usize].remove(j);
                self.num_edges -= 1;
                self.version += 1;
                true
            }
        }
    }

    pub fn label(&self, v: VertexId) -> Label {
        self.labels.as_ref().map_or(0, |l| l[v as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;

    #[test]
    fn insert_remove_roundtrip() {
        let mut g = DynGraph::new(4);
        assert!(g.insert_edge(0, 1));
        assert!(!g.insert_edge(1, 0), "duplicate rejected");
        assert!(g.insert_edge(1, 2));
        assert_eq!(g.num_edges(), 2);
        assert!(g.has_edge(0, 1));
        assert!(g.remove_edge(0, 1));
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.neighbors(1), &[2]);
    }

    #[test]
    fn grows_on_demand() {
        let mut g = DynGraph::new(0);
        g.insert_edge(5, 9);
        assert_eq!(g.num_vertices(), 10);
        assert!(g.has_edge(9, 5));
    }

    #[test]
    fn csr_conversion_roundtrip() {
        let g0 = erdos_renyi(60, 200, 5);
        let dg = DynGraph::from_data_graph(&g0);
        let g1 = dg.to_data_graph("rt");
        assert_eq!(g0.num_edges(), g1.num_edges());
        for v in 0..60 {
            assert_eq!(g0.neighbors(v), g1.neighbors(v));
        }
    }

    #[test]
    fn version_counts_applied_mutations_only() {
        let mut g = DynGraph::new(4);
        assert_eq!(g.version(), 0);
        assert!(g.insert_edge(0, 1));
        assert_eq!(g.version(), 1);
        assert!(!g.insert_edge(1, 0), "duplicate insert is a no-op");
        assert_eq!(g.version(), 1, "no-op must not bump the epoch");
        assert!(g.remove_edge(0, 1));
        assert_eq!(g.version(), 2);
        assert!(!g.remove_edge(0, 1));
        assert_eq!(g.version(), 2);
    }

    #[test]
    fn fingerprint_agrees_with_csr_and_tracks_mutations() {
        let g0 = erdos_renyi(50, 170, 0xF1);
        let mut dg = DynGraph::from_data_graph(&g0);
        // DynGraph and the CSR it converts to/from hash identically
        assert_eq!(dg.fingerprint(), g0.fingerprint());
        assert_eq!(dg.fingerprint(), dg.to_data_graph("x").fingerprint());
        let fp0 = dg.fingerprint();
        // applied mutations change the fingerprint; undo restores it
        let (u, v) = (0..50u32)
            .flat_map(|a| (0..50u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !dg.has_edge(a, b))
            .expect("sparse graph has a non-edge");
        assert!(dg.insert_edge(u, v));
        let fp1 = dg.fingerprint();
        assert_ne!(fp1, fp0);
        assert_eq!(fp1, dg.to_data_graph("x").fingerprint());
        assert!(dg.remove_edge(u, v));
        assert_eq!(dg.fingerprint(), fp0, "content-keyed: undo restores identity");
        // no-op mutations leave it untouched (unlike nothing else observable)
        let before = dg.fingerprint();
        assert!(!dg.remove_edge(u, v));
        assert_eq!(dg.fingerprint(), before);
    }

    #[test]
    fn adjacency_stays_sorted() {
        let mut g = DynGraph::new(5);
        for (u, v) in [(0, 4), (0, 2), (0, 3), (0, 1)] {
            g.insert_edge(u, v);
        }
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
    }
}
