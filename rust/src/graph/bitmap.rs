//! Dense bitmap adjacency rows for hub vertices — the "hybrid" half of the
//! hybrid CSR representation.
//!
//! Power-law graphs concentrate a large fraction of all edge endpoints on a
//! few hub vertices. Set operations against a hub's adjacency list dominate
//! the matching inner loop, and a sorted-list merge touches the whole list.
//! A bitmap row gives O(1) membership instead, so intersecting a candidate
//! buffer with a hub operand costs O(|buf|) regardless of the hub's degree.
//!
//! Invariants (checked by [`crate::graph::DataGraph::check_invariants`]):
//! * a bitmap row exists only for vertices selected by [`HubParams`]
//!   (top-degree vertices under an adaptive degree floor, row count capped
//!   relative to the CSR size);
//! * row `r` of hub `h` has bit `u` set **iff** `u` appears in the sorted
//!   CSR adjacency list of `h` — the CSR list remains authoritative and is
//!   kept for every vertex, hubs included;
//! * rows are `ceil(n / 64)` words, bits beyond `n` are zero.

use super::VertexId;

/// Hard safety clamp on the number of bitmap rows, regardless of what the
/// measured distribution asks for. The working cap is the CSR-relative
/// budget in [`HubParams::from_degree_distribution`]; this only bounds
/// pathological inputs.
pub const MAX_HUB_ROWS_CLAMP: usize = 4096;

/// Hub-row selection parameters, derived from the **measured** degree
/// distribution of the graph being built (not fixed constants): the degree
/// floor and row cap adapt to the graph's size and skew.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HubParams {
    /// Minimum degree for a vertex to get a bitmap row.
    pub min_degree: usize,
    /// Maximum number of rows (heaviest vertices win).
    pub max_rows: usize,
}

impl HubParams {
    /// Derive parameters from a graph with `n` vertices and `deg_sum` total
    /// edge endpoints (`Σ d(v) = 2m`).
    ///
    /// * `min_degree` — a row costs `n` bits, so the sorted list it shadows
    ///   must be within a factor 64 of that (`deg ≥ n/64`), never below 64
    ///   neighbors (merges are already cheap there), and at least 4× the
    ///   measured average degree so "hub" stays meaningful on degree-flat
    ///   graphs where no vertex is exceptional.
    /// * `max_rows` — total row storage is budgeted at roughly the CSR
    ///   neighbor array itself: one row is `n/8` bytes vs `4` bytes per
    ///   stored endpoint, giving `32 · deg_sum / n` (= 32 × average degree)
    ///   rows, clamped to `[16, MAX_HUB_ROWS_CLAMP]` and to `n`.
    pub fn from_degree_distribution(n: usize, deg_sum: usize) -> HubParams {
        if n == 0 {
            return HubParams {
                min_degree: 64,
                max_rows: 0,
            };
        }
        let avg = deg_sum as f64 / n as f64;
        let min_degree = (n / 64).max((4.0 * avg).ceil() as usize).max(64);
        let max_rows = ((32.0 * avg).round() as usize)
            .clamp(16, MAX_HUB_ROWS_CLAMP)
            .min(n);
        HubParams {
            min_degree,
            max_rows,
        }
    }
}

/// Bitmap adjacency rows for the hub vertices of one data graph.
#[derive(Clone, Debug)]
pub struct HubBitmaps {
    /// Words per row: `ceil(n / 64)`.
    words_per_row: usize,
    /// `row_of[v]` = row index of `v`, or `u32::MAX` if `v` is not a hub.
    row_of: Vec<u32>,
    /// `hubs[r]` = vertex owning row `r` (descending degree).
    hubs: Vec<VertexId>,
    /// Row-major bit storage, `hubs.len() * words_per_row` words.
    bits: Vec<u64>,
    /// The adaptive selection parameters this index was built with.
    params: HubParams,
}

/// A borrowed bitmap row: O(1) membership for one hub's neighborhood.
#[derive(Clone, Copy, Debug)]
pub struct HubRow<'a> {
    words: &'a [u64],
}

impl HubRow<'_> {
    /// Whether `v` is a neighbor of the row's hub.
    #[inline]
    pub fn contains(&self, v: VertexId) -> bool {
        let w = (v >> 6) as usize;
        debug_assert!(w < self.words.len());
        (self.words[w] >> (v & 63)) & 1 == 1
    }

    /// Raw words (for word-wise AND/ANDNOT between two hub rows).
    #[inline]
    pub fn words(&self) -> &[u64] {
        self.words
    }
}

impl HubBitmaps {
    /// Build rows for the top-degree vertices of a CSR graph, with selection
    /// parameters derived from the graph's own degree distribution
    /// ([`HubParams::from_degree_distribution`]). Returns `None` when no
    /// vertex qualifies (small or degree-flat graphs).
    pub fn build(offsets: &[usize], neighbors: &[VertexId]) -> Option<HubBitmaps> {
        let n = offsets.len() - 1;
        let params = HubParams::from_degree_distribution(n, neighbors.len());
        let mut hubs: Vec<VertexId> = (0..n as VertexId)
            .filter(|&v| offsets[v as usize + 1] - offsets[v as usize] >= params.min_degree)
            .collect();
        if hubs.is_empty() {
            return None;
        }
        // keep the heaviest rows under the memory cap; deterministic order
        hubs.sort_by_key(|&v| {
            (
                std::cmp::Reverse(offsets[v as usize + 1] - offsets[v as usize]),
                v,
            )
        });
        hubs.truncate(params.max_rows);

        let words_per_row = n.div_ceil(64);
        let mut row_of = vec![u32::MAX; n];
        let mut bits = vec![0u64; hubs.len() * words_per_row];
        for (r, &h) in hubs.iter().enumerate() {
            row_of[h as usize] = r as u32;
            let row = &mut bits[r * words_per_row..(r + 1) * words_per_row];
            for &u in &neighbors[offsets[h as usize]..offsets[h as usize + 1]] {
                row[(u >> 6) as usize] |= 1u64 << (u & 63);
            }
        }
        Some(HubBitmaps {
            words_per_row,
            row_of,
            hubs,
            bits,
            params,
        })
    }

    /// The adaptive selection parameters this index was built with.
    pub fn params(&self) -> HubParams {
        self.params
    }

    /// Bitmap row of `v`, if `v` is a hub.
    #[inline]
    pub fn row(&self, v: VertexId) -> Option<HubRow<'_>> {
        let r = *self.row_of.get(v as usize)?;
        if r == u32::MAX {
            return None;
        }
        let start = r as usize * self.words_per_row;
        Some(HubRow {
            words: &self.bits[start..start + self.words_per_row],
        })
    }

    /// The hub vertices owning rows, heaviest first.
    pub fn hubs(&self) -> &[VertexId] {
        &self.hubs
    }

    /// Number of bitmap rows.
    pub fn num_rows(&self) -> usize {
        self.hubs.len()
    }
}

/// `out = a ∩ b` where `b` is a hub bitmap row: per-element O(1) membership.
pub fn intersect_row_into(a: &[VertexId], b: HubRow<'_>, out: &mut Vec<VertexId>) {
    out.clear();
    out.extend(a.iter().copied().filter(|&x| b.contains(x)));
}

/// `out = a ∩ b ∩ (lo, hi)` where **both** operands are hub bitmap rows —
/// the two-operand case of [`fold_rows_into`].
pub fn intersect_rows_into(
    a: HubRow<'_>,
    b: HubRow<'_>,
    lo: Option<VertexId>,
    hi: Option<VertexId>,
    out: &mut Vec<VertexId>,
) {
    fold_rows_into(&[a, b], &[], lo, hi, out);
}

/// `out = (⋂ and_rows) \ (⋃ sub_rows) ∩ (lo, hi)` over hub bitmap rows:
/// one word-wise AND/ANDNOT sweep, emitting set bits inside the open
/// window. This is the heaviest candidate-set case (every operand a hub
/// adjacency list, intersections *and* subtractions) reduced to `n/64`
/// word ops per operand. `and_rows` must be non-empty; bits beyond the
/// vertex range stay clear because every AND row keeps them zero.
pub fn fold_rows_into(
    and_rows: &[HubRow<'_>],
    sub_rows: &[HubRow<'_>],
    lo: Option<VertexId>,
    hi: Option<VertexId>,
    out: &mut Vec<VertexId>,
) {
    out.clear();
    let first = and_rows.first().expect("need at least one AND operand");
    let words = first.words().len();
    debug_assert!(and_rows.iter().chain(sub_rows).all(|r| r.words().len() == words));
    let start_bit = lo.map_or(0, |v| v as usize + 1);
    let end_bit = hi.map_or(words * 64, |v| v as usize);
    if start_bit >= end_bit {
        return;
    }
    let start_w = start_bit >> 6;
    let end_w = ((end_bit + 63) >> 6).min(words);
    for w in start_w..end_w {
        let mut bits = first.words()[w];
        for r in &and_rows[1..] {
            bits &= r.words()[w];
        }
        for r in sub_rows {
            bits &= !r.words()[w];
        }
        if w == start_w {
            bits &= !0u64 << (start_bit & 63);
        }
        if w == end_bit >> 6 && (end_bit & 63) != 0 {
            bits &= (1u64 << (end_bit & 63)) - 1;
        }
        while bits != 0 {
            let t = bits.trailing_zeros() as usize;
            out.push((w * 64 + t) as VertexId);
            bits &= bits - 1;
        }
    }
}

/// `out = a \ b` where `b` is a hub bitmap row.
pub fn difference_row_into(a: &[VertexId], b: HubRow<'_>, out: &mut Vec<VertexId>) {
    out.clear();
    out.extend(a.iter().copied().filter(|&x| !b.contains(x)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// A star graph whose center qualifies as a hub (degree ≥ 64).
    fn star(leaves: usize) -> crate::graph::DataGraph {
        let edges: Vec<(u32, u32)> = (1..=leaves as u32).map(|v| (0, v)).collect();
        GraphBuilder::new().edges(&edges).build("star")
    }

    #[test]
    fn star_center_gets_a_row() {
        let g = star(100);
        assert_eq!(g.hub_count(), 1);
        let row = g.hub_row(0).expect("center is a hub");
        for v in 1..=100u32 {
            assert!(row.contains(v));
        }
        assert!(!row.contains(0));
        assert!(g.hub_row(1).is_none(), "leaves are not hubs");
    }

    #[test]
    fn small_graphs_have_no_rows() {
        let g = star(10);
        assert_eq!(g.hub_count(), 0);
        assert!(g.hub_row(0).is_none());
    }

    #[test]
    fn row_ops_match_sorted_ops() {
        let g = star(80);
        let row = g.hub_row(0).unwrap();
        let cands: Vec<u32> = vec![0, 1, 5, 77, 80, 81];
        let mut out = Vec::new();
        intersect_row_into(&cands, row, &mut out);
        assert_eq!(out, vec![1, 5, 77, 80]);
        difference_row_into(&cands, row, &mut out);
        assert_eq!(out, vec![0, 81]);
    }

    #[test]
    fn word_wise_and_respects_window() {
        // two hubs sharing 70 neighbors: 0 and 1 both connected to 2..=71
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for v in 2..=71u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        edges.push((0, 72)); // only hub 0
        let g = GraphBuilder::new().edges(&edges).build("two-hubs");
        let (r0, r1) = (g.hub_row(0).unwrap(), g.hub_row(1).unwrap());
        let mut out = Vec::new();
        intersect_rows_into(r0, r1, None, None, &mut out);
        assert_eq!(out, (2..=71u32).collect::<Vec<_>>());
        // open window (10, 65): strictly between
        intersect_rows_into(r0, r1, Some(10), Some(65), &mut out);
        assert_eq!(out, (11..=64u32).collect::<Vec<_>>());
        // window at word boundaries
        intersect_rows_into(r0, r1, Some(63), Some(64), &mut out);
        assert!(out.is_empty());
        intersect_rows_into(r0, r1, Some(62), None, &mut out);
        assert_eq!(out, (63..=71u32).collect::<Vec<_>>());
    }

    #[test]
    fn params_derive_from_measured_distribution() {
        // sparse mid-size graph: the n/64 term stays below the 64 floor
        let p = HubParams::from_degree_distribution(1000, 6000);
        assert_eq!(p.min_degree, 64);
        // large sparse graph: n/64 dominates
        let p = HubParams::from_degree_distribution(64_000, 640_000);
        assert_eq!(p.min_degree, 1000);
        // degree-flat dense graph: the 4×avg term raises the floor so flat
        // graphs don't declare half their vertices "hubs"
        let p = HubParams::from_degree_distribution(2000, 2000 * 40);
        assert_eq!(p.min_degree, 160);
        // row cap follows the CSR budget (32 × average degree), clamped
        let p = HubParams::from_degree_distribution(100_000, 100_000 * 22);
        assert_eq!(p.max_rows, 704);
        // avg 2 → 64 rows by budget, bounded by the vertex count
        let p = HubParams::from_degree_distribution(50, 100);
        assert_eq!(p.max_rows, 50);
        assert!(HubParams::from_degree_distribution(10_000_000, 10_000_000 * 200).max_rows
            <= MAX_HUB_ROWS_CLAMP);
    }

    #[test]
    fn built_index_reports_params() {
        let g = star(100);
        // star(100): n = 101, deg_sum = 200 → avg ≈ 1.98 → floor stays 64
        let p = g.hub_params().expect("star center is a hub");
        assert_eq!(p.min_degree, 64);
        assert!(p.max_rows >= 16);
        assert_eq!(g.hub_count(), 1);
    }

    #[test]
    fn fold_rows_single_row_and_empty_subtract_set() {
        // satellite boundary case: one AND operand, no subtract operands —
        // the fold must reproduce exactly the row's own bit set
        let g = star(80);
        let row = g.hub_row(0).expect("center is a hub");
        let mut out = Vec::new();
        fold_rows_into(&[row], &[], None, None, &mut out);
        assert_eq!(out, (1..=80u32).collect::<Vec<_>>());
        // windowed single row
        fold_rows_into(&[row], &[], Some(10), Some(20), &mut out);
        assert_eq!(out, (11..20u32).collect::<Vec<_>>());
        // degenerate windows are empty, not wrapped
        fold_rows_into(&[row], &[], Some(20), Some(10), &mut out);
        assert!(out.is_empty());
        fold_rows_into(&[row], &[], Some(15), Some(16), &mut out);
        assert!(out.is_empty(), "open interval (15,16) holds nothing");
        // subtracting the row from itself erases everything
        fold_rows_into(&[row], &[row], None, None, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "need at least one AND operand")]
    fn fold_rows_empty_and_set_is_a_contract_violation() {
        // satellite boundary case: the empty AND operand set is outside
        // the documented contract (there is no universe row to start
        // from) and must fail loudly, not return garbage
        let none: &[HubRow<'_>] = &[];
        let mut out = Vec::new();
        fold_rows_into(none, none, None, None, &mut out);
    }

    #[test]
    fn fold_rows_across_word_boundaries() {
        // satellite boundary case: neighbor sets and vertex counts that
        // straddle the 64-bit word boundary. Hubs 0 and 1 share neighbors
        // 60..=68 (bits on both sides of word 0/word 1), and the graph has
        // 130 vertices so rows span three words with a partial last word.
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for v in 60..=68u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        // pad both to hub degree (≥ 64) with disjoint leaves
        for v in 69..=124u32 {
            edges.push((0, v));
        }
        for v in 2..58u32 {
            edges.push((1, v));
        }
        let g = GraphBuilder::new().edges(&edges).num_vertices(130).build("boundary");
        let (r0, r1) = (g.hub_row(0).expect("hub 0"), g.hub_row(1).expect("hub 1"));
        let mut out = Vec::new();
        fold_rows_into(&[r0, r1], &[], None, None, &mut out);
        assert_eq!(out, (60..=68u32).collect::<Vec<_>>(), "overlap crosses the word seam");
        // windows pinned exactly on the seam
        fold_rows_into(&[r0, r1], &[], Some(63), None, &mut out);
        assert_eq!(out, (64..=68u32).collect::<Vec<_>>());
        fold_rows_into(&[r0, r1], &[], None, Some(64), &mut out);
        assert_eq!(out, (60..=63u32).collect::<Vec<_>>());
        fold_rows_into(&[r0, r1], &[], Some(63), Some(65), &mut out);
        assert_eq!(out, vec![64]);
        // subtraction across the seam
        fold_rows_into(&[r0], &[r1], Some(59), Some(70), &mut out);
        assert_eq!(out, vec![69], "shared seam bits all cancel");
        // window end beyond the last vertex clamps to the row width
        fold_rows_into(&[r0], &[], Some(120), Some(4096), &mut out);
        assert_eq!(out, (121..=124u32).collect::<Vec<_>>());
    }

    #[test]
    fn fold_rows_andnot_matches_naive() {
        // three hubs over a shared leaf universe: 0 and 1 share 3..=70,
        // hub 2 covers 40..=90 — folding 0∩1\2 must drop the upper overlap
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for v in 3..=70u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        for v in 40..=90u32 {
            edges.push((2, v));
        }
        // make all three genuine hubs (degree ≥ 64)
        for v in 91..=120u32 {
            edges.push((2, v));
        }
        let g = GraphBuilder::new().edges(&edges).build("three-hubs");
        let (r0, r1, r2) = (
            g.hub_row(0).expect("hub 0"),
            g.hub_row(1).expect("hub 1"),
            g.hub_row(2).expect("hub 2"),
        );
        let mut out = Vec::new();
        fold_rows_into(&[r0, r1], &[r2], None, None, &mut out);
        assert_eq!(out, (3..40u32).collect::<Vec<_>>());
        // windowed: open interval (10, 30)
        fold_rows_into(&[r0, r1], &[r2], Some(10), Some(30), &mut out);
        assert_eq!(out, (11..30u32).collect::<Vec<_>>());
        // two subtract rows erase everything
        fold_rows_into(&[r0], &[r1, r2], None, None, &mut out);
        let naive: Vec<u32> = (0..=120u32)
            .filter(|&v| r0.contains(v) && !r1.contains(v) && !r2.contains(v))
            .collect();
        assert_eq!(out, naive);
        // consistency with the 2-row wrapper
        let mut out2 = Vec::new();
        intersect_rows_into(r0, r1, Some(5), Some(66), &mut out2);
        fold_rows_into(&[r0, r1], &[], Some(5), Some(66), &mut out);
        assert_eq!(out, out2);
    }
}
