//! Dense bitmap adjacency rows for hub vertices — the "hybrid" half of the
//! hybrid CSR representation.
//!
//! Power-law graphs concentrate a large fraction of all edge endpoints on a
//! few hub vertices. Set operations against a hub's adjacency list dominate
//! the matching inner loop, and a sorted-list merge touches the whole list.
//! A bitmap row gives O(1) membership instead, so intersecting a candidate
//! buffer with a hub operand costs O(|buf|) regardless of the hub's degree.
//!
//! Invariants (checked by [`crate::graph::DataGraph::check_invariants`]):
//! * a bitmap row exists only for vertices selected by [`hub_threshold`]
//!   (top-degree vertices, capped at [`MAX_HUB_ROWS`]);
//! * row `r` of hub `h` has bit `u` set **iff** `u` appears in the sorted
//!   CSR adjacency list of `h` — the CSR list remains authoritative and is
//!   kept for every vertex, hubs included;
//! * rows are `ceil(n / 64)` words, bits beyond `n` are zero.

use super::VertexId;

/// Upper bound on bitmap rows (memory cap: `MAX_HUB_ROWS * n / 8` bytes).
pub const MAX_HUB_ROWS: usize = 256;

/// Minimum degree for a vertex to get a bitmap row: the row costs `n` bits,
/// so demand the sorted list be within a factor 64 of that (`deg >= n/64`),
/// and never bother below 64 neighbors where merges are already cheap.
pub fn hub_threshold(num_vertices: usize) -> usize {
    (num_vertices / 64).max(64)
}

/// Bitmap adjacency rows for the hub vertices of one data graph.
#[derive(Clone, Debug)]
pub struct HubBitmaps {
    /// Words per row: `ceil(n / 64)`.
    words_per_row: usize,
    /// `row_of[v]` = row index of `v`, or `u32::MAX` if `v` is not a hub.
    row_of: Vec<u32>,
    /// `hubs[r]` = vertex owning row `r` (descending degree).
    hubs: Vec<VertexId>,
    /// Row-major bit storage, `hubs.len() * words_per_row` words.
    bits: Vec<u64>,
}

/// A borrowed bitmap row: O(1) membership for one hub's neighborhood.
#[derive(Clone, Copy, Debug)]
pub struct HubRow<'a> {
    words: &'a [u64],
}

impl HubRow<'_> {
    /// Whether `v` is a neighbor of the row's hub.
    #[inline]
    pub fn contains(&self, v: VertexId) -> bool {
        let w = (v >> 6) as usize;
        debug_assert!(w < self.words.len());
        (self.words[w] >> (v & 63)) & 1 == 1
    }

    /// Raw words (for word-wise AND/ANDNOT between two hub rows).
    #[inline]
    pub fn words(&self) -> &[u64] {
        self.words
    }
}

impl HubBitmaps {
    /// Build rows for the top-degree vertices of a CSR graph. Returns `None`
    /// when no vertex qualifies (small or degree-flat graphs).
    pub fn build(offsets: &[usize], neighbors: &[VertexId]) -> Option<HubBitmaps> {
        let n = offsets.len() - 1;
        let min_deg = hub_threshold(n);
        let mut hubs: Vec<VertexId> = (0..n as VertexId)
            .filter(|&v| offsets[v as usize + 1] - offsets[v as usize] >= min_deg)
            .collect();
        if hubs.is_empty() {
            return None;
        }
        // keep the heaviest rows under the memory cap; deterministic order
        hubs.sort_by_key(|&v| {
            (
                std::cmp::Reverse(offsets[v as usize + 1] - offsets[v as usize]),
                v,
            )
        });
        hubs.truncate(MAX_HUB_ROWS);

        let words_per_row = n.div_ceil(64);
        let mut row_of = vec![u32::MAX; n];
        let mut bits = vec![0u64; hubs.len() * words_per_row];
        for (r, &h) in hubs.iter().enumerate() {
            row_of[h as usize] = r as u32;
            let row = &mut bits[r * words_per_row..(r + 1) * words_per_row];
            for &u in &neighbors[offsets[h as usize]..offsets[h as usize + 1]] {
                row[(u >> 6) as usize] |= 1u64 << (u & 63);
            }
        }
        Some(HubBitmaps {
            words_per_row,
            row_of,
            hubs,
            bits,
        })
    }

    /// Bitmap row of `v`, if `v` is a hub.
    #[inline]
    pub fn row(&self, v: VertexId) -> Option<HubRow<'_>> {
        let r = *self.row_of.get(v as usize)?;
        if r == u32::MAX {
            return None;
        }
        let start = r as usize * self.words_per_row;
        Some(HubRow {
            words: &self.bits[start..start + self.words_per_row],
        })
    }

    /// The hub vertices owning rows, heaviest first.
    pub fn hubs(&self) -> &[VertexId] {
        &self.hubs
    }

    /// Number of bitmap rows.
    pub fn num_rows(&self) -> usize {
        self.hubs.len()
    }
}

/// `out = a ∩ b` where `b` is a hub bitmap row: per-element O(1) membership.
pub fn intersect_row_into(a: &[VertexId], b: HubRow<'_>, out: &mut Vec<VertexId>) {
    out.clear();
    out.extend(a.iter().copied().filter(|&x| b.contains(x)));
}

/// `out = a ∩ b ∩ (lo, hi)` where **both** operands are hub bitmap rows:
/// word-wise AND over the two rows, emitting set bits inside the open
/// window. This is the heaviest intersection case (two hub adjacency
/// lists) reduced to `n/64` word ops.
pub fn intersect_rows_into(
    a: HubRow<'_>,
    b: HubRow<'_>,
    lo: Option<VertexId>,
    hi: Option<VertexId>,
    out: &mut Vec<VertexId>,
) {
    out.clear();
    let (aw, bw) = (a.words(), b.words());
    debug_assert_eq!(aw.len(), bw.len());
    let words = aw.len();
    let start_bit = lo.map_or(0, |v| v as usize + 1);
    let end_bit = hi.map_or(words * 64, |v| v as usize);
    if start_bit >= end_bit {
        return;
    }
    let start_w = start_bit >> 6;
    let end_w = ((end_bit + 63) >> 6).min(words);
    for w in start_w..end_w {
        let mut bits = aw[w] & bw[w];
        if w == start_w {
            bits &= !0u64 << (start_bit & 63);
        }
        if w == end_bit >> 6 && (end_bit & 63) != 0 {
            bits &= (1u64 << (end_bit & 63)) - 1;
        }
        while bits != 0 {
            let t = bits.trailing_zeros() as usize;
            out.push((w * 64 + t) as VertexId);
            bits &= bits - 1;
        }
    }
}

/// `out = a \ b` where `b` is a hub bitmap row.
pub fn difference_row_into(a: &[VertexId], b: HubRow<'_>, out: &mut Vec<VertexId>) {
    out.clear();
    out.extend(a.iter().copied().filter(|&x| !b.contains(x)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// A star graph whose center qualifies as a hub (degree ≥ 64).
    fn star(leaves: usize) -> crate::graph::DataGraph {
        let edges: Vec<(u32, u32)> = (1..=leaves as u32).map(|v| (0, v)).collect();
        GraphBuilder::new().edges(&edges).build("star")
    }

    #[test]
    fn star_center_gets_a_row() {
        let g = star(100);
        assert_eq!(g.hub_count(), 1);
        let row = g.hub_row(0).expect("center is a hub");
        for v in 1..=100u32 {
            assert!(row.contains(v));
        }
        assert!(!row.contains(0));
        assert!(g.hub_row(1).is_none(), "leaves are not hubs");
    }

    #[test]
    fn small_graphs_have_no_rows() {
        let g = star(10);
        assert_eq!(g.hub_count(), 0);
        assert!(g.hub_row(0).is_none());
    }

    #[test]
    fn row_ops_match_sorted_ops() {
        let g = star(80);
        let row = g.hub_row(0).unwrap();
        let cands: Vec<u32> = vec![0, 1, 5, 77, 80, 81];
        let mut out = Vec::new();
        intersect_row_into(&cands, row, &mut out);
        assert_eq!(out, vec![1, 5, 77, 80]);
        difference_row_into(&cands, row, &mut out);
        assert_eq!(out, vec![0, 81]);
    }

    #[test]
    fn word_wise_and_respects_window() {
        // two hubs sharing 70 neighbors: 0 and 1 both connected to 2..=71
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for v in 2..=71u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        edges.push((0, 72)); // only hub 0
        let g = GraphBuilder::new().edges(&edges).build("two-hubs");
        let (r0, r1) = (g.hub_row(0).unwrap(), g.hub_row(1).unwrap());
        let mut out = Vec::new();
        intersect_rows_into(r0, r1, None, None, &mut out);
        assert_eq!(out, (2..=71u32).collect::<Vec<_>>());
        // open window (10, 65): strictly between
        intersect_rows_into(r0, r1, Some(10), Some(65), &mut out);
        assert_eq!(out, (11..=64u32).collect::<Vec<_>>());
        // window at word boundaries
        intersect_rows_into(r0, r1, Some(63), Some(64), &mut out);
        assert!(out.is_empty());
        intersect_rows_into(r0, r1, Some(62), None, &mut out);
        assert_eq!(out, (63..=71u32).collect::<Vec<_>>());
    }

    #[test]
    fn threshold_scales_with_graph_size() {
        assert_eq!(hub_threshold(1000), 64);
        assert_eq!(hub_threshold(64_000), 1000);
    }
}
