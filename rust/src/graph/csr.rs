//! Compressed-sparse-row data graph with sorted adjacency lists, optionally
//! extended to a **hybrid adjacency**: dense bitmap rows for hub vertices
//! alongside the sorted lists.
//!
//! This is the substrate the matching engine explores. Invariants:
//! * undirected simple graph: every edge appears in both endpoint lists,
//!   no self loops, no duplicates;
//! * each adjacency list is sorted ascending — required by the tiered
//!   intersection/difference kernels in [`crate::exec::intersect`];
//! * optional vertex labels, dense in `0..num_labels`.
//!
//! # Hybrid-adjacency invariants
//!
//! The sorted CSR list is authoritative and exists for **every** vertex;
//! the bitmap rows of [`super::bitmap::HubBitmaps`] are a redundant index
//! over the heaviest lists:
//! * `hub_row(v).is_some()` only for top-degree vertices (see
//!   [`super::bitmap::HubParams`] — the degree floor and row cap adapt to
//!   the measured degree distribution); any vertex may be queried;
//! * when a row exists, `row.contains(u) == neighbors(v).contains(&u)` for
//!   all `u` — kernels may use whichever side is cheaper (`common_neighbors`
//!   style membership loops should prefer the row: O(1) per probe instead
//!   of a binary search over a list that can span millions of entries);
//! * rows are rebuilt whenever the CSR parts change; there is no partial
//!   update path (the graph is immutable).
//!
//! When the graph was built with degree-ordered relabeling
//! ([`super::relabel::Relabeling`]), the engine-facing IDs are the
//! *relabeled* ones (hubs at 0, 1, …) and [`DataGraph::original_id`] maps
//! back to the input IDs for user-facing output.

use super::bitmap::{HubBitmaps, HubRow};
use super::relabel::Relabeling;
use super::{Label, VertexId};

/// Stable identity of a graph's **content**: order, size and a streamed
/// hash of the engine-facing (relabeled) adjacency structure plus labels.
///
/// Unlike [`super::DynGraph::version`] — an in-process mutation counter
/// that restarts at zero with every process — the fingerprint is a pure
/// function of the graph the engine actually explores, so it is meaningful
/// **across processes**: persisted results keyed by a fingerprint are
/// servable exactly when the live graph hashes to the same value, and a
/// store persisted against a different or mutated graph is structurally
/// unservable. Two graphs with equal fingerprints that differ only in
/// their original-ID maps or dataset names yield identical match counts,
/// so neither enters the hash.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GraphFingerprint {
    /// Number of vertices.
    pub order: u64,
    /// Number of undirected edges.
    pub size: u64,
    /// FNV-1a hash of the adjacency lists (and labels, when present).
    pub hash: u64,
}

impl GraphFingerprint {
    /// Serialized width (`order`, `size`, `hash`, little-endian).
    pub const BYTES: usize = 24;

    pub fn to_bytes(self) -> [u8; Self::BYTES] {
        let mut b = [0u8; Self::BYTES];
        b[..8].copy_from_slice(&self.order.to_le_bytes());
        b[8..16].copy_from_slice(&self.size.to_le_bytes());
        b[16..].copy_from_slice(&self.hash.to_le_bytes());
        b
    }

    pub fn from_bytes(b: &[u8]) -> Option<GraphFingerprint> {
        if b.len() != Self::BYTES {
            return None;
        }
        Some(GraphFingerprint {
            order: u64::from_le_bytes(b[..8].try_into().ok()?),
            size: u64::from_le_bytes(b[8..16].try_into().ok()?),
            hash: u64::from_le_bytes(b[16..].try_into().ok()?),
        })
    }
}

impl std::fmt::Display for GraphFingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "|V|={} |E|={} hash={:016x}", self.order, self.size, self.hash)
    }
}

/// Streaming FNV-1a (64-bit) used by the graph fingerprints. Deliberately
/// not `DefaultHasher`: the persisted-store format needs a hash that is
/// stable across processes, platforms and Rust versions.
struct StreamHasher(u64);

impl StreamHasher {
    fn new() -> StreamHasher {
        StreamHasher(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    fn write_u8(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// The one definition of the fingerprint hash stream, shared by
/// [`DataGraph::fingerprint`] and [`super::DynGraph::fingerprint`] so the
/// two representations can never silently diverge (the warm-restart
/// invariant of [`crate::service::persist`] depends on their equality).
pub(crate) fn fingerprint_of<'a>(
    n: usize,
    num_edges: usize,
    lists: impl Iterator<Item = &'a [VertexId]>,
    labels: Option<&[Label]>,
) -> GraphFingerprint {
    let mut h = StreamHasher::new();
    h.write_u64(n as u64);
    for ns in lists {
        h.write_u64(ns.len() as u64);
        for &u in ns {
            h.write_u32(u);
        }
    }
    match labels {
        Some(l) => {
            h.write_u8(1);
            for &x in l {
                h.write_u32(x);
            }
        }
        None => h.write_u8(0),
    }
    GraphFingerprint {
        order: n as u64,
        size: num_edges as u64,
        hash: h.finish(),
    }
}

/// An immutable undirected data graph in hybrid CSR form.
#[derive(Clone, Debug)]
pub struct DataGraph {
    offsets: Vec<usize>,
    neighbors: Vec<VertexId>,
    labels: Option<Vec<Label>>,
    num_labels: u32,
    name: String,
    /// Old↔new ID map when the build relabeled vertices (`None` = identity).
    relabel: Option<Relabeling>,
    /// Bitmap rows for hub vertices (`None` = no vertex qualifies or the
    /// builder disabled them).
    hubs: Option<HubBitmaps>,
}

impl DataGraph {
    /// Build from parts. `neighbors[offsets[v]..offsets[v+1]]` must be the
    /// sorted neighbor list of `v`. Hub bitmap rows are derived
    /// automatically. Prefer [`crate::graph::GraphBuilder`].
    pub fn from_parts(
        offsets: Vec<usize>,
        neighbors: Vec<VertexId>,
        labels: Option<Vec<Label>>,
        name: String,
    ) -> Self {
        Self::from_parts_opts(offsets, neighbors, labels, name, None, true)
    }

    /// [`DataGraph::from_parts`] with an explicit relabeling record and a
    /// switch for the hub bitmap index (the kernels ablation measures the
    /// list-only representation against the hybrid one).
    pub fn from_parts_opts(
        offsets: Vec<usize>,
        neighbors: Vec<VertexId>,
        labels: Option<Vec<Label>>,
        name: String,
        relabel: Option<Relabeling>,
        hub_bitmaps: bool,
    ) -> Self {
        debug_assert!(!offsets.is_empty());
        let num_labels = labels
            .as_ref()
            .map(|l| l.iter().copied().max().map_or(0, |m| m + 1))
            .unwrap_or(0);
        let hubs = if hub_bitmaps {
            HubBitmaps::build(&offsets, &neighbors)
        } else {
            None
        };
        let relabel = relabel.filter(|r| !r.is_identity());
        let g = DataGraph {
            offsets,
            neighbors,
            labels,
            num_labels,
            name,
            relabel,
            hubs,
        };
        debug_assert!(g.check_invariants());
        g
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Sorted neighbor list of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.neighbors[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Whether `(u, v)` is an edge. Hub rows answer in O(1); otherwise a
    /// binary search over the smaller sorted list.
    #[inline]
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        if let Some(row) = self.hub_row(u) {
            return row.contains(v);
        }
        if let Some(row) = self.hub_row(v) {
            return row.contains(u);
        }
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        self.neighbors(a).binary_search(&b).is_ok()
    }

    /// Bitmap row of `v`, if `v` is a hub in the hybrid representation.
    #[inline]
    pub fn hub_row(&self, v: VertexId) -> Option<HubRow<'_>> {
        self.hubs.as_ref().and_then(|h| h.row(v))
    }

    /// Number of hub vertices carrying bitmap rows.
    pub fn hub_count(&self) -> usize {
        self.hubs.as_ref().map_or(0, |h| h.num_rows())
    }

    /// The adaptive hub-selection parameters the bitmap index was built
    /// with (`None` when the graph carries no index).
    pub fn hub_params(&self) -> Option<super::bitmap::HubParams> {
        self.hubs.as_ref().map(|h| h.params())
    }

    /// The hub vertices carrying bitmap rows, heaviest first.
    pub fn hub_vertices(&self) -> &[VertexId] {
        match &self.hubs {
            Some(h) => h.hubs(),
            None => &[],
        }
    }

    /// The relabeling applied at build time, if any.
    pub fn relabeling(&self) -> Option<&Relabeling> {
        self.relabel.as_ref()
    }

    /// Content fingerprint of this CSR: order, size and a streamed hash of
    /// the (engine-facing) adjacency lists and labels. See
    /// [`GraphFingerprint`] for what is deliberately excluded. O(|V|+|E|);
    /// callers that need it repeatedly should cache it alongside the
    /// snapshot it describes.
    pub fn fingerprint(&self) -> GraphFingerprint {
        let n = self.num_vertices();
        fingerprint_of(
            n,
            self.num_edges(),
            (0..n as VertexId).map(|v| self.neighbors(v)),
            self.labels.as_deref(),
        )
    }

    /// Original (input) ID of engine vertex `v` — identity unless the graph
    /// was built with degree-ordered relabeling.
    #[inline]
    pub fn original_id(&self, v: VertexId) -> VertexId {
        match &self.relabel {
            Some(r) => r.old_id(v),
            None => v,
        }
    }

    /// A copy of this graph without the hub bitmap index (kernels ablation:
    /// sorted lists only).
    pub fn without_hub_bitmaps(&self) -> DataGraph {
        let mut g = self.clone();
        g.hubs = None;
        g
    }

    /// Label of `v` (0 for unlabeled graphs).
    #[inline]
    pub fn label(&self, v: VertexId) -> Label {
        self.labels.as_ref().map_or(0, |l| l[v as usize])
    }

    /// Whether the graph carries labels.
    #[inline]
    pub fn is_labeled(&self) -> bool {
        self.labels.is_some()
    }

    /// Number of distinct labels (`0` for unlabeled graphs).
    #[inline]
    pub fn num_labels(&self) -> u32 {
        self.num_labels
    }

    /// Dataset name (for reports).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Verify CSR + hybrid-adjacency invariants (debug builds / tests).
    pub fn check_invariants(&self) -> bool {
        let n = self.num_vertices();
        if *self.offsets.last().unwrap() != self.neighbors.len() {
            return false;
        }
        if let Some(l) = &self.labels {
            if l.len() != n {
                return false;
            }
        }
        if let Some(r) = &self.relabel {
            if r.len() != n || !r.check() {
                return false;
            }
        }
        for v in 0..n as VertexId {
            let ns = self.neighbors(v);
            for w in ns.windows(2) {
                if w[0] >= w[1] {
                    return false; // unsorted or duplicate
                }
            }
            for &u in ns {
                if u as usize >= n || u == v {
                    return false; // out of range or self loop
                }
                // symmetry
                if self.neighbors(u).binary_search(&v).is_err() {
                    return false;
                }
            }
            // hub rows must agree with the sorted list exactly
            if let Some(row) = self.hub_row(v) {
                let mut count = 0usize;
                for u in 0..n as VertexId {
                    if row.contains(u) {
                        count += 1;
                        if ns.binary_search(&u).is_err() {
                            return false;
                        }
                    }
                }
                if count != ns.len() {
                    return false;
                }
            }
        }
        true
    }

    /// Densify a vertex subset into a 0/1 adjacency matrix of size
    /// `block.len() × block.len()` (row-major f32) — feed for the XLA dense
    /// census backend. Uses a position vector indexed by vertex ID (not a
    /// hash map): the census backend calls this per block, and large blocks
    /// made hashing the hot spot.
    pub fn densify(&self, block: &[VertexId]) -> Vec<f32> {
        let k = block.len();
        let mut a = vec![0f32; k * k];
        // position of each block vertex, indexed by vertex id
        let mut pos = vec![u32::MAX; self.num_vertices()];
        for (i, &v) in block.iter().enumerate() {
            pos[v as usize] = i as u32;
        }
        for (i, &v) in block.iter().enumerate() {
            for &u in self.neighbors(v) {
                let j = pos[u as usize];
                if j != u32::MAX {
                    a[i * k + j as usize] = 1.0;
                }
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::GraphFingerprint;
    use crate::graph::GraphBuilder;

    fn triangle_plus_tail() -> crate::graph::DataGraph {
        // 0-1, 1-2, 2-0 triangle; 2-3 tail
        GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 0), (2, 3)])
            .build("t")
    }

    #[test]
    fn basic_accessors() {
        let g = triangle_plus_tail();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
        assert!(!g.is_labeled());
        assert_eq!(g.max_degree(), 3);
        assert_eq!(g.original_id(2), 2, "identity without relabeling");
        assert!(g.relabeling().is_none());
    }

    #[test]
    fn invariants_hold() {
        let g = triangle_plus_tail();
        assert!(g.check_invariants());
    }

    #[test]
    fn hub_rows_answer_has_edge() {
        let edges: Vec<(u32, u32)> = (1..=100).map(|v| (0, v)).collect();
        let g = GraphBuilder::new().edges(&edges).build("star");
        assert_eq!(g.hub_count(), 1);
        assert_eq!(g.hub_vertices(), &[0]);
        assert!(g.has_edge(0, 57));
        assert!(g.has_edge(57, 0));
        assert!(!g.has_edge(1, 2));
        assert!(g.check_invariants());
        let stripped = g.without_hub_bitmaps();
        assert_eq!(stripped.hub_count(), 0);
        assert!(stripped.has_edge(0, 57), "list path still works");
        assert!(stripped.check_invariants());
    }

    #[test]
    fn fingerprint_tracks_content_not_provenance() {
        let g = triangle_plus_tail();
        let fp = g.fingerprint();
        assert_eq!(fp.order, 4);
        assert_eq!(fp.size, 4);
        // identical content under a different name → identical fingerprint
        let same = GraphBuilder::new()
            .edges(&[(2, 0), (2, 3), (0, 1), (1, 2)])
            .build("other-name");
        assert_eq!(same.fingerprint(), fp);
        // one edge more → different fingerprint
        let more = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 0), (2, 3), (1, 3)])
            .build("t");
        assert_ne!(more.fingerprint(), fp);
        // same order/size, different wiring → hash must differ
        let rewired = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 0)])
            .build("square");
        assert_eq!(rewired.fingerprint().order, fp.order);
        assert_eq!(rewired.fingerprint().size, fp.size);
        assert_ne!(rewired.fingerprint().hash, fp.hash);
        // labels enter the hash
        let labeled = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 0), (2, 3)])
            .labels(vec![0, 0, 0, 1])
            .build("t");
        assert_ne!(labeled.fingerprint(), fp);
        // hub-bitmap presence is an index, not content
        assert_eq!(g.without_hub_bitmaps().fingerprint(), fp);
        // byte round trip
        assert_eq!(GraphFingerprint::from_bytes(&fp.to_bytes()), Some(fp));
        assert_eq!(GraphFingerprint::from_bytes(&[0u8; 7]), None);
    }

    #[test]
    fn densify_block() {
        let g = triangle_plus_tail();
        let a = g.densify(&[0, 1, 2]);
        // triangle on the block: all off-diagonal ones
        assert_eq!(a, vec![0., 1., 1., 1., 0., 1., 1., 1., 0.]);
        let a2 = g.densify(&[0, 3]);
        assert_eq!(a2, vec![0., 0., 0., 0.]);
    }
}
