//! Compressed-sparse-row data graph with sorted adjacency lists.
//!
//! This is the substrate the matching engine explores. Invariants:
//! * undirected simple graph: every edge appears in both endpoint lists,
//!   no self loops, no duplicates;
//! * each adjacency list is sorted ascending — required by the galloping
//!   intersection/difference kernels in [`crate::exec::intersect`];
//! * optional vertex labels, dense in `0..num_labels`.

use super::{Label, VertexId};

/// An immutable undirected data graph in CSR form.
#[derive(Clone, Debug)]
pub struct DataGraph {
    offsets: Vec<usize>,
    neighbors: Vec<VertexId>,
    labels: Option<Vec<Label>>,
    num_labels: u32,
    name: String,
}

impl DataGraph {
    /// Build from parts. `neighbors[offsets[v]..offsets[v+1]]` must be the
    /// sorted neighbor list of `v`. Prefer [`crate::graph::GraphBuilder`].
    pub fn from_parts(
        offsets: Vec<usize>,
        neighbors: Vec<VertexId>,
        labels: Option<Vec<Label>>,
        name: String,
    ) -> Self {
        debug_assert!(!offsets.is_empty());
        let num_labels = labels
            .as_ref()
            .map(|l| l.iter().copied().max().map_or(0, |m| m + 1))
            .unwrap_or(0);
        let g = DataGraph {
            offsets,
            neighbors,
            labels,
            num_labels,
            name,
        };
        debug_assert!(g.check_invariants());
        g
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Sorted neighbor list of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        &self.neighbors[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Whether `(u, v)` is an edge (binary search; lists are sorted).
    #[inline]
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        self.neighbors(a).binary_search(&b).is_ok()
    }

    /// Label of `v` (0 for unlabeled graphs).
    #[inline]
    pub fn label(&self, v: VertexId) -> Label {
        self.labels.as_ref().map_or(0, |l| l[v as usize])
    }

    /// Whether the graph carries labels.
    #[inline]
    pub fn is_labeled(&self) -> bool {
        self.labels.is_some()
    }

    /// Number of distinct labels (`0` for unlabeled graphs).
    #[inline]
    pub fn num_labels(&self) -> u32 {
        self.num_labels
    }

    /// Dataset name (for reports).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Verify CSR invariants (debug builds / tests).
    pub fn check_invariants(&self) -> bool {
        let n = self.num_vertices();
        if *self.offsets.last().unwrap() != self.neighbors.len() {
            return false;
        }
        if let Some(l) = &self.labels {
            if l.len() != n {
                return false;
            }
        }
        for v in 0..n as VertexId {
            let ns = self.neighbors(v);
            for w in ns.windows(2) {
                if w[0] >= w[1] {
                    return false; // unsorted or duplicate
                }
            }
            for &u in ns {
                if u as usize >= n || u == v {
                    return false; // out of range or self loop
                }
                // symmetry
                if self.neighbors(u).binary_search(&v).is_err() {
                    return false;
                }
            }
        }
        true
    }

    /// Densify a vertex subset into a 0/1 adjacency matrix of size
    /// `block.len() × block.len()` (row-major f32) — feed for the XLA dense
    /// census backend.
    pub fn densify(&self, block: &[VertexId]) -> Vec<f32> {
        let k = block.len();
        let mut a = vec![0f32; k * k];
        // position of each block vertex
        let mut pos = std::collections::HashMap::with_capacity(k);
        for (i, &v) in block.iter().enumerate() {
            pos.insert(v, i);
        }
        for (i, &v) in block.iter().enumerate() {
            for &u in self.neighbors(v) {
                if let Some(&j) = pos.get(&u) {
                    a[i * k + j] = 1.0;
                }
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::GraphBuilder;

    fn triangle_plus_tail() -> crate::graph::DataGraph {
        // 0-1, 1-2, 2-0 triangle; 2-3 tail
        GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 0), (2, 3)])
            .build("t")
    }

    #[test]
    fn basic_accessors() {
        let g = triangle_plus_tail();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 3));
        assert!(!g.is_labeled());
        assert_eq!(g.max_degree(), 3);
    }

    #[test]
    fn invariants_hold() {
        let g = triangle_plus_tail();
        assert!(g.check_invariants());
    }

    #[test]
    fn densify_block() {
        let g = triangle_plus_tail();
        let a = g.densify(&[0, 1, 2]);
        // triangle on the block: all off-diagonal ones
        assert_eq!(
            a,
            vec![0., 1., 1., 1., 0., 1., 1., 1., 0.]
        );
        let a2 = g.densify(&[0, 3]);
        assert_eq!(a2, vec![0., 0., 0., 0.]);
    }
}
