//! Graph construction: dedupe, symmetrize, sort, optional degree-based
//! vertex renaming (Peregrine normalizes IDs so that higher-degree vertices
//! get smaller IDs, which improves the effectiveness of ID-order symmetry
//! breaking and aligns symmetry windows with adjacency-list prefixes).
//!
//! The rename is recorded as a [`Relabeling`] on the built [`DataGraph`],
//! so user-facing outputs (enumeration, IO) can map engine IDs back to the
//! input IDs.

use super::relabel::Relabeling;
use super::{csr::DataGraph, Label, VertexId};

/// Builder for [`DataGraph`]: accepts an arbitrary multiset of (possibly
/// duplicated, self-looped, unordered) edges and produces a clean CSR.
pub struct GraphBuilder {
    edges: Vec<(VertexId, VertexId)>,
    labels: Option<Vec<Label>>,
    n_hint: usize,
    degree_order: bool,
    hub_bitmaps: bool,
}

impl Default for GraphBuilder {
    fn default() -> Self {
        GraphBuilder {
            edges: Vec::new(),
            labels: None,
            n_hint: 0,
            degree_order: false,
            hub_bitmaps: true,
        }
    }
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one undirected edge.
    pub fn edge(mut self, u: VertexId, v: VertexId) -> Self {
        self.edges.push((u, v));
        self
    }

    /// Add many edges.
    pub fn edges(mut self, es: &[(VertexId, VertexId)]) -> Self {
        self.edges.extend_from_slice(es);
        self
    }

    /// Provide per-vertex labels (indexed by the *input* vertex IDs).
    pub fn labels(mut self, labels: Vec<Label>) -> Self {
        self.labels = Some(labels);
        self
    }

    /// Ensure at least `n` vertices even if some are isolated.
    pub fn num_vertices(mut self, n: usize) -> Self {
        self.n_hint = n;
        self
    }

    /// Rename vertices so higher-degree vertices receive smaller IDs. The
    /// old↔new map is kept on the graph ([`DataGraph::original_id`]).
    pub fn degree_ordered(mut self, yes: bool) -> Self {
        self.degree_order = yes;
        self
    }

    /// Build dense bitmap rows for hub vertices (default on; the kernels
    /// ablation turns it off to measure the sorted-list-only layout).
    pub fn hub_bitmaps(mut self, yes: bool) -> Self {
        self.hub_bitmaps = yes;
        self
    }

    /// Finalize into a [`DataGraph`].
    pub fn build(self, name: &str) -> DataGraph {
        let GraphBuilder {
            mut edges,
            labels,
            n_hint,
            degree_order,
            hub_bitmaps,
        } = self;

        // drop self loops, normalize direction
        edges.retain(|&(u, v)| u != v);
        for e in edges.iter_mut() {
            if e.0 > e.1 {
                *e = (e.1, e.0);
            }
        }
        edges.sort_unstable();
        edges.dedup();

        let n = edges
            .iter()
            .map(|&(u, v)| (u.max(v) as usize) + 1)
            .max()
            .unwrap_or(0)
            .max(n_hint)
            .max(labels.as_ref().map_or(0, |l| l.len()));

        // optional degree-ordered rename, recorded on the graph
        let (edges, labels, relabel) = if degree_order {
            let mut deg = vec![0usize; n];
            for &(u, v) in &edges {
                deg[u as usize] += 1;
                deg[v as usize] += 1;
            }
            let r = Relabeling::degree_descending(&deg);
            let edges: Vec<_> = edges
                .iter()
                .map(|&(u, v)| {
                    let (a, b) = (r.new_id(u), r.new_id(v));
                    if a < b {
                        (a, b)
                    } else {
                        (b, a)
                    }
                })
                .collect();
            let labels = labels.map(|l| {
                let mut nl = vec![0; n];
                for (old, &lab) in l.iter().enumerate() {
                    nl[r.new_id(old as VertexId) as usize] = lab;
                }
                nl
            });
            (edges, labels, Some(r))
        } else {
            (edges, labels, None)
        };

        // CSR
        let mut deg = vec![0usize; n];
        for &(u, v) in &edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + deg[v];
        }
        let mut cursor = offsets.clone();
        let mut neighbors = vec![0 as VertexId; offsets[n]];
        for &(u, v) in &edges {
            neighbors[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            neighbors[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        for v in 0..n {
            neighbors[offsets[v]..offsets[v + 1]].sort_unstable();
        }

        let labels = labels.map(|mut l| {
            l.resize(n, 0);
            l
        });

        DataGraph::from_parts_opts(
            offsets,
            neighbors,
            labels,
            name.to_string(),
            relabel,
            hub_bitmaps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedupes_and_symmetrizes() {
        let g = GraphBuilder::new()
            .edges(&[(1, 0), (0, 1), (1, 1), (2, 1)])
            .build("g");
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn isolated_vertices_via_hint() {
        let g = GraphBuilder::new().edge(0, 1).num_vertices(5).build("g");
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.degree(4), 0);
    }

    #[test]
    fn labels_carried() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2)])
            .labels(vec![5, 6, 5])
            .build("g");
        assert!(g.is_labeled());
        assert_eq!(g.label(0), 5);
        assert_eq!(g.label(1), 6);
        assert_eq!(g.num_labels(), 7);
    }

    #[test]
    fn degree_order_renames_hub_to_zero() {
        // star centered at 3
        let g = GraphBuilder::new()
            .edges(&[(3, 0), (3, 1), (3, 2), (3, 4)])
            .degree_ordered(true)
            .build("g");
        assert_eq!(g.degree(0), 4, "hub should be renamed to vertex 0");
        assert!(g.check_invariants());
    }

    #[test]
    fn degree_order_preserves_labels() {
        let g = GraphBuilder::new()
            .edges(&[(3, 0), (3, 1), (3, 2)])
            .labels(vec![9, 9, 9, 1])
            .degree_ordered(true)
            .build("g");
        assert_eq!(g.label(0), 1, "hub label must follow the rename");
    }

    #[test]
    fn degree_order_records_original_ids() {
        let g = GraphBuilder::new()
            .edges(&[(3, 0), (3, 1), (3, 2), (3, 4)])
            .degree_ordered(true)
            .build("g");
        assert_eq!(g.original_id(0), 3, "engine hub 0 was input vertex 3");
        let r = g.relabeling().expect("relabeling recorded");
        assert!(r.check());
        assert_eq!(r.new_id(3), 0);
        // neighbors of the hub map back to the original leaf ids
        let mut orig: Vec<u32> = g.neighbors(0).iter().map(|&u| g.original_id(u)).collect();
        orig.sort_unstable();
        assert_eq!(orig, vec![0, 1, 2, 4]);
    }

    #[test]
    fn hub_bitmaps_toggle() {
        let edges: Vec<(u32, u32)> = (1..=90).map(|v| (0, v)).collect();
        let on = GraphBuilder::new().edges(&edges).build("g");
        assert_eq!(on.hub_count(), 1);
        let off = GraphBuilder::new()
            .edges(&edges)
            .hub_bitmaps(false)
            .build("g");
        assert_eq!(off.hub_count(), 0);
    }
}
