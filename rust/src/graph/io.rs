//! Graph IO: a simple text format compatible with common edge lists.
//!
//! ```text
//! # comment
//! v <id> <label>      (optional labeled-vertex lines)
//! e <u> <v>           (edge lines; plain "<u> <v>" also accepted)
//! ```
//!
//! Loaded graphs are degree-order relabeled for the matching engine (hubs
//! get the smallest IDs); the old↔new map is kept on the [`DataGraph`] so
//! [`save_text`] and user-facing outputs report the file's original IDs.

use super::{DataGraph, GraphBuilder, Label, VertexId};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Load a graph from the text format above.
pub fn load_text(path: &Path) -> Result<DataGraph> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening graph file {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut labels: Vec<(VertexId, Label)> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let first = it.next().unwrap();
        match first {
            "v" => {
                let id: VertexId = it
                    .next()
                    .context("v line missing id")?
                    .parse()
                    .with_context(|| format!("line {}", lineno + 1))?;
                let lab: Label = it
                    .next()
                    .context("v line missing label")?
                    .parse()
                    .with_context(|| format!("line {}", lineno + 1))?;
                labels.push((id, lab));
            }
            "e" => {
                let u: VertexId = it.next().context("e line missing u")?.parse()?;
                let v: VertexId = it.next().context("e line missing v")?.parse()?;
                edges.push((u, v));
            }
            tok => {
                let u: VertexId = tok.parse().with_context(|| {
                    format!("line {}: expected vertex id, got {tok:?}", lineno + 1)
                })?;
                let v: VertexId = it
                    .next()
                    .with_context(|| format!("line {}: missing second endpoint", lineno + 1))?
                    .parse()?;
                edges.push((u, v));
            }
        }
    }
    if edges.is_empty() && labels.is_empty() {
        bail!("empty graph file {}", path.display());
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "graph".into());
    let mut b = GraphBuilder::new().edges(&edges).degree_ordered(true);
    if !labels.is_empty() {
        let n = labels
            .iter()
            .map(|&(v, _)| v as usize + 1)
            .max()
            .unwrap_or(0)
            .max(edges.iter().map(|&(u, v)| u.max(v) as usize + 1).max().unwrap_or(0));
        let mut lab = vec![0 as Label; n];
        for (v, l) in labels {
            lab[v as usize] = l;
        }
        b = b.labels(lab);
    }
    Ok(b.build(&name))
}

/// Save a graph in the text format above, reporting **original** vertex IDs
/// (the inverse of the degree-ordered relabeling applied at build time, when
/// there is one).
pub fn save_text(g: &DataGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating graph file {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# morphmine graph: {} |V|={} |E|={}",
        g.name(),
        g.num_vertices(),
        g.num_edges()
    )?;
    if g.is_labeled() {
        for v in 0..g.num_vertices() as VertexId {
            writeln!(w, "v {} {}", g.original_id(v), g.label(v))?;
        }
    }
    for v in 0..g.num_vertices() as VertexId {
        for &u in g.neighbors(v) {
            if v < u {
                writeln!(w, "e {} {}", g.original_id(v), g.original_id(u))?;
            }
        }
    }
    Ok(())
}

/// Graph spec used on the CLI: either a dataset name
/// (`mico|patents|youtube|orkut[:tiny|small|medium]`) or a path to a file.
pub fn load_spec(spec: &str) -> Result<DataGraph> {
    use crate::graph::generators::{Dataset, Scale};
    let (name, scale) = match spec.split_once(':') {
        Some((n, s)) => (
            n,
            Scale::parse(s).with_context(|| format!("bad scale {s:?}"))?,
        ),
        None => (spec, Scale::Small),
    };
    if let Some(d) = Dataset::parse(name) {
        return Ok(d.generate(scale));
    }
    load_text(Path::new(spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;

    #[test]
    fn roundtrip_unlabeled() {
        let g = erdos_renyi(50, 120, 1);
        let dir = std::env::temp_dir().join("morphmine_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g1.txt");
        save_text(&g, &p).unwrap();
        let g2 = load_text(&p).unwrap();
        assert_eq!(g.num_vertices(), g2.num_vertices());
        assert_eq!(g.num_edges(), g2.num_edges());
        // loading relabels by degree; compare through the original-id map
        for v2 in 0..g2.num_vertices() as u32 {
            let mut back: Vec<u32> = g2.neighbors(v2).iter().map(|&u| g2.original_id(u)).collect();
            back.sort_unstable();
            assert_eq!(back, g.neighbors(g2.original_id(v2)));
        }
    }

    #[test]
    fn roundtrip_labeled() {
        let g = crate::graph::generators::assign_labels(erdos_renyi(30, 60, 2), 5, 1.5, 3);
        let dir = std::env::temp_dir().join("morphmine_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g2.txt");
        save_text(&g, &p).unwrap();
        let g2 = load_text(&p).unwrap();
        assert!(g2.is_labeled());
        for v2 in 0..g2.num_vertices() as u32 {
            assert_eq!(g.label(g2.original_id(v2)), g2.label(v2));
        }
    }

    #[test]
    fn save_reports_original_ids() {
        // engine ids are relabeled after load; the file written back must be
        // in the same id space as the input file
        let dir = std::env::temp_dir().join("morphmine_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g4.txt");
        // star centered at 9: the loader renames 9 to engine id 0
        std::fs::write(&p, "e 9 1\ne 9 2\ne 9 3\n").unwrap();
        let g = load_text(&p).unwrap();
        assert_eq!(g.degree(0), 3, "hub relabeled to id 0");
        assert_eq!(g.original_id(0), 9);
        let p2 = dir.join("g4_out.txt");
        save_text(&g, &p2).unwrap();
        let body = std::fs::read_to_string(&p2).unwrap();
        assert!(body.contains("9"), "original hub id must appear: {body}");
        let g2 = load_text(&p2).unwrap();
        assert_eq!(g2.original_id(0), 9, "roundtrip keeps original ids");
    }

    #[test]
    fn plain_edge_list_accepted() {
        let dir = std::env::temp_dir().join("morphmine_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g3.txt");
        std::fs::write(&p, "# c\n0 1\n1 2\n").unwrap();
        let g = load_text(&p).unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn load_spec_dataset() {
        let g = load_spec("mico:tiny").unwrap();
        assert_eq!(g.name(), "mico-sim");
        assert!(load_spec("unknown:bogus").is_err());
    }
}
