//! Ablation benchmarks for the design choices DESIGN.md calls out:
//!
//! * **A1 — symmetry breaking**: Grochow–Kellis ordering constraints vs
//!   exploring all automorphic images (Peregrine's key substrate property;
//!   without it the E/I-vs-V/I cost asymmetries that morphing exploits
//!   change magnitude).
//! * **A2 — set-intersection strategy**: galloping vs forced linear merge
//!   on the skewed adjacency lists of power-law graphs.
//! * **A3 — cost-model fidelity**: does the §4.1 cost model *rank* patterns
//!   the way measured match times rank them? (That ranking is all the
//!   optimizer needs — absolute values are irrelevant.)
//! * **A4 — incremental vs batch recount** on an update stream.
//! * **A5 — approximate counting + exact morphing conversion**: estimator
//!   error across sample budgets.
//! * **A6 — fused multi-pattern co-execution**: one shared-prefix trie
//!   traversal for the whole base set vs one sweep per pattern, across
//!   counting, MNI and enumeration aggregations (reports wall time,
//!   first-level traversal counts and trie sharing; written to
//!   `BENCH_fused.json`, path overridable via `MM_FUSED_JSON`).
//! * **A7 — kernel tiers × graph representation**: scalar vs SIMD set
//!   kernels and original vs degree-relabeled vertex order vs the full
//!   hybrid (relabeled + hub bitmap) representation, on power-law and
//!   uniform generator graphs (written to `BENCH_kernels.json`, path
//!   overridable via `MM_KERNELS_JSON`).
//! * **A8 — service-layer result cache**: cold vs warm vs
//!   overlapping-batch throughput through `morphmine`'s batched query
//!   service (written to `BENCH_service.json`, path overridable via
//!   `MM_SERVICE_JSON`).
//! * **A9 — durable result store**: cold start vs warm restart (snapshot
//!   recovery) vs replay-heavy restart (WAL-only recovery, no snapshot)
//!   through the persistence layer, including recovery latencies
//!   (written to `BENCH_persist.json`, path overridable via
//!   `MM_PERSIST_JSON`).
//! * **A10 — first-level sharding**: one batch through a coordinator over
//!   1/2/4 local shard workers vs the single-process service, answers
//!   asserted identical (written to `BENCH_shard.json`, path overridable
//!   via `MM_SHARD_JSON`).
//!
//! JSON reports go through [`write_rows_json`]: a payload with zero
//! measured rows (a placeholder) is loudly warned about and never
//! overwrites a file that already holds measured rows. Every row carries
//! a `"metrics"` snapshot of the process registry ([`with_metrics`]) as
//! counter evidence for the layer the row claims to measure.

use crate::agg::{aggregate_pattern, aggregate_patterns_fused, EnumerateAgg, MniAgg};
use crate::apps;
use crate::exec;
use crate::exec::intersect::{force_tier, simd_active, Tier};
use crate::graph::generators::{erdos_renyi, Dataset, Scale};
use crate::graph::{DataGraph, DynGraph, GraphBuilder, GraphStats, VertexId};
use crate::morph::{self, Policy};
use crate::pattern::{catalog, Pattern};
use crate::plan::cost::{estimate, CostParams};
use crate::plan::fused::FusedPlan;
use crate::plan::Plan;
use crate::util::timer::Timer;
use anyhow::Result;

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Does `path` already hold a JSON report with at least one measured row
/// (`"rows": [ { … ] ` with content)? String-level check — the bench JSON
/// is machine-written, and the crate has no JSON parser offline.
fn existing_measured_rows(path: &std::path::Path) -> bool {
    let Ok(body) = std::fs::read_to_string(path) else {
        return false;
    };
    match body.find("\"rows\"") {
        Some(i) => match body[i..].find('[') {
            Some(j) => body[i + j + 1..].trim_start().starts_with('{'),
            None => false,
        },
        None => false,
    }
}

/// Write a bench JSON report, guarding measured data against placeholders:
/// a payload with `n_rows == 0` never overwrites a file that already holds
/// measured rows (warn + keep), and writing a fresh placeholder warns so
/// the emptiness is impossible to miss in logs.
fn write_rows_json(out: &std::path::Path, json: &str, n_rows: usize) -> Result<()> {
    if n_rows == 0 {
        if existing_measured_rows(out) {
            eprintln!(
                "warning: {} already holds measured rows; refusing to overwrite with a placeholder",
                out.display()
            );
            return Ok(());
        }
        eprintln!(
            "warning: writing placeholder with zero measured rows to {}",
            out.display()
        );
    }
    std::fs::write(out, json)?;
    println!("\nwrote {} ({n_rows} rows)", out.display());
    Ok(())
}

/// Append a snapshot of the process metric registry to a bench row —
/// counter evidence that the measured path actually exercised the layers
/// it claims (nonzero `mm_kernel_ops_total{tier="…"}` under the kernel
/// ablation, `mm_fused_node_visits_total` under the fused one, shard and
/// WAL series under theirs). The registry is process-cumulative, so
/// consumers diff consecutive rows for per-row deltas.
fn with_metrics(row: String) -> String {
    let mut r = row;
    assert_eq!(r.pop(), Some('}'), "bench rows are JSON objects");
    r.push_str(", \"metrics\": ");
    r.push_str(&crate::obs::render_json(crate::obs::global()));
    r.push('}');
    r
}

/// A1: symmetry breaking on/off.
pub fn ablation_symmetry(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A1 — symmetry breaking (match times, s)\n");
    println!("| graph | pattern | with | without | speedup |");
    println!("|-------|---------|------|---------|---------|");
    for d in [Dataset::MicoSim, Dataset::OrkutSim] {
        let g = d.generate(scale);
        for (name, p) in [
            ("triangle", catalog::triangle()),
            ("cycle4^E", catalog::cycle(4)),
            ("cycle4^V", catalog::cycle(4).vertex_induced()),
            ("clique4", catalog::clique(4)),
        ] {
            let with_plan = Plan::compile(&p);
            let without_plan = Plan::compile_opts(&p, false);
            let (c_with, t_with) =
                time(|| exec::parallel::par_count_matches(&g, &with_plan, threads));
            let (c_without, t_without) =
                time(|| exec::parallel::par_count_matches(&g, &without_plan, threads));
            assert_eq!(c_with * with_plan.aut_count as u64, c_without);
            println!(
                "| {} | {name} | {t_with:.3} | {t_without:.3} | {:.2}× |",
                d.code(),
                t_without / t_with.max(1e-9)
            );
        }
    }
    Ok(())
}

/// A2: galloping vs linear intersections (micro, synthetic skew).
pub fn ablation_intersections() -> Result<()> {
    println!("\n### A2 — intersection kernels (ns/op, synthetic skew)\n");
    println!("| |small| | |large| | galloping | linear |");
    println!("|---------|---------|-----------|--------|");
    let mut rng = crate::util::rng::Rng::new(0xA2);
    for (ns, nl) in [(16usize, 200_000usize), (256, 100_000), (4096, 65536)] {
        let mut small: Vec<u32> = (0..ns).map(|_| rng.below(1_000_000) as u32).collect();
        let mut large: Vec<u32> = (0..nl).map(|_| rng.below(1_000_000) as u32).collect();
        small.sort_unstable();
        small.dedup();
        large.sort_unstable();
        large.dedup();
        let mut out = Vec::new();
        let reps = 2000;
        let (_, t_gallop) = time(|| {
            for _ in 0..reps {
                exec::intersect::intersect_into(&small, &large, &mut out);
                std::hint::black_box(&out);
            }
        });
        // forced linear merge: same sizes, use the non-galloping path by
        // intersecting two equal-ish lists after slicing large
        let (_, t_linear) = time(|| {
            for _ in 0..reps {
                linear_intersect(&small, &large, &mut out);
                std::hint::black_box(&out);
            }
        });
        println!(
            "| {} | {} | {:.0} | {:.0} |",
            small.len(),
            large.len(),
            t_gallop / reps as f64 * 1e9,
            t_linear / reps as f64 * 1e9
        );
    }
    Ok(())
}

fn linear_intersect(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// A3: cost-model ranking fidelity (Spearman footrule vs measured times).
pub fn ablation_cost_model(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A3 — cost-model ranking vs measured match times\n");
    println!("| graph | pattern | predicted rank | measured rank | measured (s) |");
    println!("|-------|---------|----------------|---------------|--------------|");
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let g = d.generate(scale);
        let stats = GraphStats::compute(&g, 2000, 3);
        let pats = [
            ("triangle", catalog::triangle()),
            ("cycle4^E", catalog::cycle(4)),
            ("cycle4^V", catalog::cycle(4).vertex_induced()),
            ("diamond^E", catalog::diamond()),
            ("clique4", catalog::clique(4)),
            ("path4^E", catalog::path(4)),
        ];
        let mut rows: Vec<(usize, f64, f64)> = pats
            .iter()
            .enumerate()
            .map(|(i, (_, p))| {
                let plan = Plan::compile(p);
                let pred = estimate(&plan, &stats, &CostParams::counting());
                let (_, secs) = time(|| exec::parallel::par_count_matches(&g, &plan, threads));
                (i, pred, secs)
            })
            .collect();
        let rank = |v: &[(usize, f64, f64)], key: fn(&(usize, f64, f64)) -> f64| {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| key(&v[a]).partial_cmp(&key(&v[b])).unwrap());
            let mut r = vec![0usize; v.len()];
            for (rankpos, &i) in idx.iter().enumerate() {
                r[i] = rankpos;
            }
            r
        };
        let pred_rank = rank(&rows, |x| x.1);
        let meas_rank = rank(&rows, |x| x.2);
        let mut footrule = 0usize;
        for i in 0..rows.len() {
            footrule += pred_rank[i].abs_diff(meas_rank[i]);
        }
        rows.sort_by_key(|&(i, _, _)| i);
        for (i, (name, _)) in pats.iter().enumerate() {
            println!(
                "| {} | {name} | {} | {} | {:.3} |",
                d.code(),
                pred_rank[i],
                meas_rank[i],
                rows[i].2
            );
        }
        println!("| {} | *footrule distance* | | {footrule} | |", d.code());
    }
    Ok(())
}

/// A4: incremental maintenance vs batch recount over an update stream.
pub fn ablation_incremental(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A4 — incremental vs batch recount (4-motifs)\n");
    let g = Dataset::MicoSim.generate(scale);
    let updates = 20usize;
    let mut rng = crate::util::rng::Rng::new(0xA4);
    let n = g.num_vertices();

    let (mut inc, t_init) = time(|| {
        apps::IncrementalMotifCounter::new(DynGraph::from_data_graph(&g), 4, threads)
    });
    let (_, t_stream) = time(|| {
        for _ in 0..updates {
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            if u != v {
                inc.insert_edge(u, v);
            }
        }
    });
    // batch recount once for comparison
    let snapshot = inc.graph().to_data_graph("ablation");
    let (_, t_batch) = time(|| apps::count_motifs(&snapshot, 4, Policy::Naive, threads));
    println!("| init (batch) | {updates} updates (incremental) | one batch recount |");
    println!("|--------------|-------------------------------|-------------------|");
    println!(
        "| {t_init:.3}s | {t_stream:.3}s ({:.1} ms/update) | {t_batch:.3}s |",
        1e3 * t_stream / updates as f64
    );
    println!(
        "\nper-update incremental cost is {:.0}× cheaper than a recount",
        t_batch / (t_stream / updates as f64).max(1e-9)
    );
    Ok(())
}

/// A5: approximate counting error vs sample budget (+ exact conversion).
pub fn ablation_approx(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A5 — approximate counting (edge-anchored sampling)\n");
    let g = Dataset::MicoSim.generate(scale);
    let exact = apps::count_motifs(&g, 4, Policy::Naive, threads);
    println!("| samples | mean relative error (motifs ≥ 100 occurrences) |");
    println!("|---------|--------------------------------------------------|");
    for frac in [0.01f64, 0.05, 0.25] {
        let samples = ((g.num_edges() as f64 * frac) as usize).max(10);
        let approx = apps::approx_motifs(&g, 4, samples, 0x55);
        let mut errs = Vec::new();
        for (p, c) in &exact.counts {
            if *c >= 100 {
                let e = approx.get(p).unwrap();
                errs.push((e - *c as f64).abs() / *c as f64);
            }
        }
        let mean = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        println!("| {samples} ({:.0}% of edges) | {mean:.3} |", frac * 100.0);
    }
    Ok(())
}

/// One A6 comparison: fused vs per-pattern for a base set under one
/// aggregation mode. Returns `(per_pattern_s, fused_s)`; results are
/// asserted equal between the two paths.
fn fused_vs_per_pattern(
    g: &DataGraph,
    base: &[Pattern],
    fused: &FusedPlan,
    mode: &str,
    threads: usize,
) -> (f64, f64) {
    match mode {
        "counting" => {
            let plans: Vec<Plan> = base.iter().map(Plan::compile).collect();
            let (per, t_per) = time(|| {
                plans
                    .iter()
                    .map(|p| exec::parallel::par_count_matches(g, p, threads))
                    .collect::<Vec<u64>>()
            });
            let (fu, t_fused) = time(|| exec::fused::fused_count_matches(g, fused, threads));
            assert_eq!(per, fu, "fused counts must equal per-pattern");
            (t_per, t_fused)
        }
        "mni" => {
            let (per, t_per) = time(|| {
                base.iter()
                    .map(|p| {
                        let agg = MniAgg {
                            n: p.num_vertices(),
                        };
                        aggregate_pattern(g, p, &agg, threads).support()
                    })
                    .collect::<Vec<u64>>()
            });
            let (fu, t_fused) = time(|| {
                // MniWidthAgg lets patterns of mixed sizes share the fused
                // traversal; values come back aligned with the base slice
                aggregate_patterns_fused(g, fused, &MniWidthAgg, threads)
                    .into_iter()
                    .map(|t| t.support())
                    .collect::<Vec<u64>>()
            });
            assert_eq!(per, fu, "fused MNI supports must equal per-pattern");
            (t_per, t_fused)
        }
        "enumerate" => {
            let (per, t_per) = time(|| {
                base.iter()
                    .map(|p| {
                        let v = aggregate_pattern(g, p, &EnumerateAgg, threads);
                        v.assert_consistent();
                        v.positive_len()
                    })
                    .collect::<Vec<u64>>()
            });
            let (fu, t_fused) = time(|| {
                aggregate_patterns_fused(g, fused, &EnumerateAgg, threads)
                    .into_iter()
                    .map(|v| {
                        v.assert_consistent();
                        v.positive_len()
                    })
                    .collect::<Vec<u64>>()
            });
            assert_eq!(per, fu, "fused enumerations must equal per-pattern");
            (t_per, t_fused)
        }
        other => unreachable!("unknown A6 mode {other}"),
    }
}

/// MNI aggregation whose width follows each match (patterns of mixed sizes
/// share one fused traversal; `accumulate` sees pattern-vertex indexing).
struct MniWidthAgg;

impl crate::agg::Aggregation for MniWidthAgg {
    type Value = crate::agg::mni::MniTable;

    fn identity(&self) -> Self::Value {
        crate::agg::mni::MniTable::default()
    }

    fn accumulate(&self, acc: &mut Self::Value, m: &[VertexId]) {
        // width-resize, then delegate to the production aggregation so the
        // multiset semantics live in exactly one place (agg/mni.rs)
        if acc.columns.len() < m.len() {
            acc.columns.resize_with(m.len(), Default::default);
        }
        MniAgg { n: m.len() }.accumulate(acc, m);
    }

    fn combine(&self, mut a: Self::Value, mut b: Self::Value) -> Self::Value {
        let w = a.columns.len().max(b.columns.len());
        a.columns.resize_with(w, Default::default);
        b.columns.resize_with(w, Default::default);
        MniAgg { n: w }.combine(a, b)
    }

    fn permute(&self, v: &Self::Value, f: &[usize]) -> Self::Value {
        // a zero-match value has no columns yet: treat missing as empty
        crate::agg::mni::MniTable {
            columns: f
                .iter()
                .map(|&fq| v.columns.get(fq).cloned().unwrap_or_default())
                .collect(),
        }
    }

    fn scale(&self, v: &Self::Value, c: i64) -> Self::Value {
        MniAgg {
            n: v.columns.len(),
        }
        .scale(v, c)
    }
}

/// A6: fused multi-pattern co-execution vs per-pattern sweeps, across
/// aggregations.
///
/// Matches the whole base pattern set through the fused plan trie in one
/// traversal and compares against one sweep per pattern, for counting
/// (4-motif sets at `scale`), MNI tables (3-motif sets at `scale`) and full
/// enumeration (3-motif V/I set at tiny scale — it materializes every
/// match). Results are asserted equal path-for-path; the fused path must do
/// strictly fewer first-level traversals. A JSON report goes to
/// `BENCH_fused.json` (or `MM_FUSED_JSON`).
pub fn ablation_fused(scale: Scale, threads: usize) -> Result<()> {
    let out = std::env::var("MM_FUSED_JSON").unwrap_or_else(|_| "BENCH_fused.json".into());
    ablation_fused_to(scale, threads, std::path::Path::new(&out))
}

/// [`ablation_fused`] with an explicit JSON output path (tests use this to
/// avoid mutating the process environment, which is UB under concurrent
/// `getenv` on glibc).
pub fn ablation_fused_to(scale: Scale, threads: usize, out: &std::path::Path) -> Result<()> {
    println!("\n### A6 — fused co-execution vs per-pattern sweeps\n");
    println!("| graph | agg | base set | per-pattern (s) | fused (s) | speedup | L0 sweeps | trie nodes / plan levels |");
    println!("|-------|-----|----------|-----------------|-----------|---------|-----------|--------------------------|");
    let naive_base = |size: usize| {
        morph::plan_queries(
            &catalog::motifs_vertex_induced(size),
            Policy::Naive,
            None,
            &CostParams::counting(),
        )
        .base
    };
    // (mode, set name, scale override, base set, datasets)
    let jobs: Vec<(&str, &str, Scale, Vec<Pattern>)> = vec![
        ("counting", "4-motif naive base", scale, naive_base(4)),
        ("counting", "4-motif V/I set", scale, catalog::motifs_vertex_induced(4)),
        ("mni", "3-motif naive base", scale, naive_base(3)),
        ("mni", "3-motif V/I set", scale, catalog::motifs_vertex_induced(3)),
        // enumeration materializes every match: pin to tiny scale
        (
            "enumerate",
            "3-motif V/I set (tiny)",
            Scale::Tiny,
            catalog::motifs_vertex_induced(3),
        ),
    ];
    let mut rows: Vec<String> = Vec::new();
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        // generate each dataset (and its stats) once per scale; jobs pinned
        // to another scale (enumeration) build their own copy below
        let g_at_scale = d.generate(scale);
        let stats_at_scale = GraphStats::compute(&g_at_scale, 2000, 0xA6);
        for (mode, name, job_scale, base) in &jobs {
            if *mode == "enumerate" && d != Dataset::MicoSim {
                continue; // materializing every match: one dataset suffices
            }
            let (g_other, stats_other);
            let (g, gstats) = if *job_scale == scale {
                (&g_at_scale, &stats_at_scale)
            } else {
                g_other = d.generate(*job_scale);
                stats_other = GraphStats::compute(&g_other, 2000, 0xA6);
                (&g_other, &stats_other)
            };
            // build the fused plan the way the production path does: order
            // selection scored against this graph's real statistics
            let fused = FusedPlan::build(base, Some(gstats), &CostParams::counting());
            let sweeps_per = base.len();
            let sweeps_fused = fused.first_level_traversals();
            assert!(
                sweeps_fused < sweeps_per,
                "fused must do strictly fewer first-level traversals ({sweeps_fused} vs {sweeps_per})"
            );
            let (t_per, t_fused) = fused_vs_per_pattern(g, base, &fused, *mode, threads);
            let speedup = t_per / t_fused.max(1e-9);
            println!(
                "| {} | {mode} | {name} | {t_per:.3} | {t_fused:.3} | {speedup:.2}× | {sweeps_per}→{sweeps_fused} | {}/{} |",
                d.code(),
                fused.nodes.len(),
                fused.total_plan_levels(),
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"agg\": \"{mode}\", \"set\": \"{name}\", \"patterns\": {}, \"per_pattern_s\": {t_per:.6}, \"fused_s\": {t_fused:.6}, \"speedup\": {speedup:.3}, \"first_level_sweeps_per_pattern\": {sweeps_per}, \"first_level_sweeps_fused\": {sweeps_fused}, \"trie_nodes\": {}, \"plan_levels\": {}}}",
                d.code(),
                base.len(),
                fused.nodes.len(),
                fused.total_plan_levels(),
            )));
        }
    }
    let json = format!(
        "{{\n  \"experiment\": \"fused_vs_per_pattern\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    write_rows_json(out, &json, rows.len())
}

/// Rebuild a graph's edge set under a chosen vertex order / adjacency
/// representation (the A7 configurations).
fn rebuild(g: &DataGraph, shuffle_seed: Option<u64>, degree_order: bool, hubs: bool) -> DataGraph {
    let n = g.num_vertices();
    // optional scrambling models arbitrary input order ("original" order —
    // the generator already emits degree-ordered ids, so un-order them)
    let perm: Vec<VertexId> = match shuffle_seed {
        Some(seed) => {
            let mut rng = crate::util::rng::Rng::new(seed);
            rng.permutation(n).into_iter().map(|v| v as VertexId).collect()
        }
        None => (0..n as VertexId).collect(),
    };
    let mut edges = Vec::with_capacity(g.num_edges());
    for v in 0..n as VertexId {
        for &u in g.neighbors(v) {
            if v < u {
                edges.push((perm[v as usize], perm[u as usize]));
            }
        }
    }
    let mut b = GraphBuilder::new()
        .edges(&edges)
        .num_vertices(n)
        .degree_ordered(degree_order)
        .hub_bitmaps(hubs);
    if g.is_labeled() {
        let mut labels = vec![0; n];
        for v in 0..n as VertexId {
            labels[perm[v as usize] as usize] = g.label(v);
        }
        b = b.labels(labels);
    }
    b.build(g.name())
}

/// A7: kernel tiers × graph representation.
///
/// Counts a fixed workload (triangle, 4-clique, vertex-induced 4-cycle, and
/// the fused 4-motif naive base) under five configurations: the scrambled
/// "original" vertex order with sorted lists and scalar kernels (baseline),
/// then SIMD kernels, degree-ordered relabeling, and the full hybrid
/// (relabeled + hub bitmap rows) stack. All counts are asserted equal —
/// the representations are isomorphic. JSON goes to `BENCH_kernels.json`
/// (or `MM_KERNELS_JSON`).
pub fn ablation_kernels(scale: Scale, threads: usize) -> Result<()> {
    let out = std::env::var("MM_KERNELS_JSON").unwrap_or_else(|_| "BENCH_kernels.json".into());
    ablation_kernels_to(scale, threads, std::path::Path::new(&out))
}

/// [`ablation_kernels`] with an explicit JSON output path (see
/// [`ablation_fused_to`] for why tests avoid the env override).
pub fn ablation_kernels_to(scale: Scale, threads: usize, out: &std::path::Path) -> Result<()> {
    println!("\n### A7 — kernel tiers × representation (match times, s)\n");
    println!("simd available: {}", simd_active());
    // power-law generator graph (the paper's regime) + uniform ER control
    let powerlaw = Dataset::MicoSim.generate(scale).without_hub_bitmaps();
    let uniform = erdos_renyi(powerlaw.num_vertices(), powerlaw.num_edges(), 0xA7);
    let graphs: [(&str, &DataGraph); 2] = [("powerlaw", &powerlaw), ("uniform", &uniform)];

    // (config name, scramble, relabel, hubs, tier)
    let configs: [(&str, Option<u64>, bool, bool, Option<Tier>); 5] = [
        ("orig+list+scalar", Some(0x5EED), false, false, Some(Tier::Scalar)),
        ("orig+list+simd", Some(0x5EED), false, false, None),
        ("relabel+list+simd", None, true, false, None),
        ("relabel+hybrid+scalar", None, true, true, Some(Tier::Scalar)),
        ("relabel+hybrid+simd", None, true, true, None),
    ];

    println!("\n| graph | config | triangle | clique4 | cycle4^V | fused 4-motif base |");
    println!("|-------|--------|----------|---------|----------|--------------------|");
    let base = morph::plan_queries(
        &catalog::motifs_vertex_induced(4),
        Policy::Naive,
        None,
        &CostParams::counting(),
    )
    .base;
    let patterns = [
        ("triangle", catalog::triangle()),
        ("clique4", catalog::clique(4)),
        ("cycle4_vi", catalog::cycle(4).vertex_induced()),
    ];
    // per-pattern plans are stats-free: compile once for all configs
    let plans: Vec<Plan> = patterns.iter().map(|(_, p)| Plan::compile(p)).collect();
    let mut rows: Vec<String> = Vec::new();
    for (gname, g) in graphs {
        let mut reference: Option<(Vec<u64>, Vec<u64>)> = None;
        for (cname, scramble, relabel, hubs, tier) in &configs {
            let variant = rebuild(g, *scramble, *relabel, *hubs);
            // fused order selection sees each variant's own statistics —
            // exactly what the production fused path executes (hub rows and
            // relabeling change the stats, hence possibly the chosen orders)
            let vstats = GraphStats::compute(&variant, 2000, 0xA7);
            let fused = FusedPlan::build(&base, Some(&vstats), &CostParams::counting());
            force_tier(*tier);
            let mut pat_counts = Vec::new();
            let mut pat_times = Vec::new();
            for plan in &plans {
                let (c, t) = time(|| exec::parallel::par_count_matches(&variant, plan, threads));
                pat_counts.push(c);
                pat_times.push(t);
            }
            let (fused_counts, t_fused) =
                time(|| exec::fused::fused_count_matches(&variant, &fused, threads));
            force_tier(None);
            match &reference {
                None => reference = Some((pat_counts.clone(), fused_counts.clone())),
                Some((rp, rf)) => {
                    assert_eq!(rp, &pat_counts, "{gname}/{cname}: counts must be invariant");
                    assert_eq!(rf, &fused_counts, "{gname}/{cname}: fused must be invariant");
                }
            }
            println!(
                "| {gname} | {cname} | {:.3} | {:.3} | {:.3} | {t_fused:.3} |",
                pat_times[0], pat_times[1], pat_times[2]
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{gname}\", \"config\": \"{cname}\", \"triangle_s\": {:.6}, \"clique4_s\": {:.6}, \"cycle4_vi_s\": {:.6}, \"fused_base_s\": {t_fused:.6}, \"total_s\": {:.6}}}",
                pat_times[0],
                pat_times[1],
                pat_times[2],
                pat_times[0] + pat_times[1] + pat_times[2] + t_fused,
            )));
        }
    }
    let json = format!(
        "{{\n  \"experiment\": \"kernel_tiers_x_representation\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"simd_available\": {},\n  \"baseline\": \"orig+list+scalar\",\n  \"rows\": [\n{}\n  ]\n}}\n",
        simd_active(),
        rows.join(",\n")
    );
    write_rows_json(out, &json, rows.len())
}

/// A8: service-layer result cache — cold vs warm vs overlapping batches.
pub fn ablation_service(scale: Scale, threads: usize) -> Result<()> {
    let out = std::env::var("MM_SERVICE_JSON").unwrap_or_else(|_| "BENCH_service.json".into());
    ablation_service_to(scale, threads, std::path::Path::new(&out))
}

/// [`ablation_service`] with an explicit JSON output path (see
/// [`ablation_fused_to`] for why tests avoid the env override).
///
/// Three measured phases per dataset, one service instance each:
/// * **cold** — a motif + match batch against an empty store (every base
///   executes);
/// * **warm** — the identical batch again (must execute **zero** bases,
///   asserted);
/// * **overlap** — a different batch whose morph plan shares part of its
///   base set with the cold batch (must execute strictly fewer bases than
///   it references, asserted); results are cross-checked against a cold
///   service.
pub fn ablation_service_to(scale: Scale, threads: usize, out: &std::path::Path) -> Result<()> {
    use crate::service::{Service, ServiceConfig};
    println!("\n### A8 — service result cache (batch latencies, s)\n");
    println!("| graph | batch | elapsed | bases | cached | executed | speedup vs cold |");
    println!("|-------|-------|---------|-------|--------|----------|-----------------|");
    let batch_a = ["motifs:4", "match:cycle4,diamond-vi"];
    let batch_b = ["match:cycle4,tailed,star4-vi", "cliques:4"];
    let mut rows: Vec<String> = Vec::new();
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let config = ServiceConfig {
            workers: 2,
            threads,
            policy: Policy::Naive, // deterministic alternative sets
            fused: true,
            cache_bytes: 64 << 20,
            persist: None,
            delta_budget: crate::service::delta::DEFAULT_DELTA_BUDGET,
        };
        let svc = Service::start(d.generate(scale), config.clone());
        let (cold, t_cold) = time(|| svc.call(&batch_a).expect("cold batch"));
        assert_eq!(cold.stats.cached_bases, 0, "first batch sees an empty store");
        let (warm, t_warm) = time(|| svc.call(&batch_a).expect("warm batch"));
        assert_eq!(
            warm.stats.executed_bases, 0,
            "warm batch over a previously-seen pattern set must execute zero bases"
        );
        assert_eq!(cold.results, warm.results, "cache must not change answers");
        let (overlap, t_overlap) = time(|| svc.call(&batch_b).expect("overlap batch"));
        assert!(
            overlap.stats.cached_bases > 0,
            "overlapping batch must reuse bases: {:?}",
            overlap.stats
        );
        assert!(
            overlap.stats.executed_bases < overlap.stats.total_bases,
            "only the missing bases may execute: {:?}",
            overlap.stats
        );
        // cross-check the partially-cached answers against a cold service
        let fresh = Service::start(d.generate(scale), config);
        let direct = fresh.call(&batch_b).expect("verification batch");
        assert_eq!(direct.results, overlap.results, "{}: partial reuse must be exact", d.code());

        for (name, t, r) in [
            ("cold", t_cold, &cold),
            ("warm", t_warm, &warm),
            ("overlap", t_overlap, &overlap),
        ] {
            let s = r.stats;
            let speedup = t_cold / t.max(1e-9);
            println!(
                "| {} | {name} | {t:.3} | {} | {} | {} | {speedup:.2}× |",
                d.code(),
                s.total_bases,
                s.cached_bases,
                s.executed_bases
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"batch\": \"{name}\", \"elapsed_s\": {t:.6}, \"total_bases\": {}, \"cached_bases\": {}, \"executed_bases\": {}, \"coalesced_bases\": {}, \"speedup_vs_cold\": {speedup:.3}}}",
                d.code(),
                s.total_bases,
                s.cached_bases,
                s.executed_bases,
                s.coalesced_bases,
            )));
        }
    }
    let json = format!(
        "{{\n  \"experiment\": \"service_result_cache\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    write_rows_json(out, &json, rows.len())
}

/// A11: delta-morphing result maintenance — in-place delta patching vs
/// purge-and-recompute under a write-heavy mixed workload.
pub fn ablation_incremental_service(scale: Scale, threads: usize) -> Result<()> {
    let out =
        std::env::var("MM_INCREMENTAL_JSON").unwrap_or_else(|_| "BENCH_incremental.json".into());
    ablation_incremental_service_to(scale, threads, std::path::Path::new(&out))
}

/// [`ablation_incremental_service`] with an explicit JSON output path (see
/// [`ablation_fused_to`] for why tests avoid the env override).
///
/// Per dataset, the same deterministic workload — warm a motif + match
/// batch, then alternate random edge updates with re-serves of that batch
/// — runs through two services that differ only in `delta_budget`:
/// * **delta-patch** — the default budget: updates delta-patch the store
///   in place, re-serves stay warm.
/// * **purge** — budget 0: every update purges the store (the pre-delta
///   behavior), re-serves recompute every base cold.
///
/// Both modes apply the identical update stream and their final answers
/// are asserted equal, so the speedup column measures maintenance
/// strategy alone, never workload drift.
pub fn ablation_incremental_service_to(
    scale: Scale,
    threads: usize,
    out: &std::path::Path,
) -> Result<()> {
    use crate::service::{Service, ServiceConfig};
    println!("\n### A11 — delta-morphing maintenance (delta-patch vs purge-and-recompute)\n");
    println!("| graph | mode | updates | total (s) | ms/update | bases recomputed | patched |");
    println!("|-------|------|---------|-----------|-----------|------------------|---------|");
    let batch = ["motifs:4", "match:cycle4,diamond-vi"];
    let updates = 12usize;
    let mut rows: Vec<String> = Vec::new();
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let n = d.generate(scale).num_vertices();
        let mut finals: Vec<crate::service::BatchResponse> = Vec::new();
        let mut purge_total = 0.0f64;
        for (mode, budget) in [
            ("purge", 0usize),
            ("delta-patch", crate::service::delta::DEFAULT_DELTA_BUDGET),
        ] {
            let svc = Service::start(
                d.generate(scale),
                ServiceConfig {
                    workers: 1,
                    threads,
                    policy: Policy::Naive,
                    fused: true,
                    cache_bytes: 64 << 20,
                    persist: None,
                    delta_budget: budget,
                },
            );
            svc.call(&batch).expect("warming batch");
            // the identical deterministic update stream for both modes
            let mut rng = crate::util::rng::Rng::new(0xA11 ^ n as u64);
            let mut executed = 0usize;
            let mut last = None;
            let (_, total_s) = time(|| {
                let mut applied = 0usize;
                while applied < updates {
                    let u = rng.below(n as u64) as u32;
                    let v = rng.below(n as u64) as u32;
                    if u == v {
                        continue;
                    }
                    let changed = if rng.below(100) < 30 {
                        svc.remove_edge(u, v).expect("in-range removal")
                    } else {
                        svc.insert_edge(u, v).expect("in-range insertion")
                    };
                    if !changed {
                        continue;
                    }
                    applied += 1;
                    let r = svc.call(&batch).expect("re-serve after update");
                    executed += r.stats.executed_bases;
                    last = Some(r);
                }
            });
            let r = last.expect("at least one update applied");
            finals.push(r);
            let m = svc.store_metrics();
            if mode == "purge" {
                purge_total = total_s;
            }
            let speedup = purge_total / total_s.max(1e-9);
            println!(
                "| {} | {mode} | {updates} | {total_s:.3} | {:.1} | {executed} | {} |",
                d.code(),
                1e3 * total_s / updates as f64,
                m.patched
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"mode\": \"{mode}\", \"updates\": {updates}, \"total_s\": {total_s:.6}, \"ms_per_update\": {:.3}, \"executed_bases\": {executed}, \"patched\": {}, \"speedup_vs_purge\": {speedup:.3}}}",
                d.code(),
                1e3 * total_s / updates as f64,
                m.patched,
            )));
        }
        assert_eq!(
            finals[0].results, finals[1].results,
            "{}: both maintenance strategies must serve identical answers",
            d.code()
        );
    }
    let json = format!(
        "{{\n  \"experiment\": \"incremental_delta_maintenance\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    write_rows_json(out, &json, rows.len())
}

/// A10: distributed first-level sharding — 1/2/4-shard scaling.
pub fn ablation_shard(scale: Scale, threads: usize) -> Result<()> {
    let out = std::env::var("MM_SHARD_JSON").unwrap_or_else(|_| "BENCH_shard.json".into());
    ablation_shard_to(scale, threads, std::path::Path::new(&out))
}

/// [`ablation_shard`] with an explicit JSON output path (see
/// [`ablation_fused_to`] for why tests avoid the env override).
///
/// Per dataset: one single-process baseline batch through the service
/// pipeline, then the same batch through a [`ShardCoordinator`] over 1, 2
/// and 4 local worker processes-in-threads, then a fault-recovery pair —
/// 3 healthy workers vs 3 healthy plus one that dies after its first
/// request (`killed_workers` 0 vs 1 in the JSON). Answers are asserted
/// **equal** to the baseline in every row (the summed partials are
/// exact); the JSON records wall-clock per shard count and the fabric's
/// failure/retry/re-fan counters for the fault rows. Workers here share
/// the host's cores with the coordinator, so tiny-scale "speedups" mostly
/// measure protocol + fan-out overhead — run at `--scale medium` on real
/// hardware (ideally with remote workers) for the scaling story.
pub fn ablation_shard_to(scale: Scale, threads: usize, out: &std::path::Path) -> Result<()> {
    use crate::service::{QueryPlanner, Service, ServiceConfig};
    use crate::shard::{ShardCoordinator, ShardWorker, WorkerConfig};
    println!("\n### A10 — first-level sharding (coordinator + N local workers)\n");
    println!("| graph | shards | batch (s) | vs single process | partials merged |");
    println!("|-------|--------|-----------|-------------------|-----------------|");
    let batch = ["motifs:4", "match:cycle4,diamond-vi"];
    let mut rows: Vec<String> = Vec::new();
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        // single-process baseline through the same service pipeline
        let svc = Service::start(
            d.generate(scale),
            ServiceConfig {
                workers: 1,
                threads,
                policy: Policy::Naive, // deterministic alternative sets
                fused: true,
                cache_bytes: 64 << 20,
                persist: None,
                delta_budget: crate::service::delta::DEFAULT_DELTA_BUDGET,
            },
        );
        let (single, t_single) = time(|| svc.call(&batch).expect("baseline batch"));
        drop(svc);
        for shards in [1usize, 2, 4] {
            let workers: Vec<ShardWorker> = (0..shards)
                .map(|_| {
                    ShardWorker::bind(
                        d.generate(scale),
                        "127.0.0.1:0",
                        WorkerConfig {
                            threads,
                            fused: true,
                            cache_bytes: 64 << 20,
                            persist: None,
                            slice_pin: None,
                        },
                    )
                    .expect("bind shard worker")
                })
                .collect();
            let addrs: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
            let planner = QueryPlanner::new(Policy::Naive, true, threads);
            let mut coord =
                ShardCoordinator::connect(d.generate(scale), &addrs, planner, 64 << 20)?;
            let (resp, t) = time(|| coord.call(&batch).expect("sharded batch"));
            assert_eq!(
                resp.results,
                single.results,
                "{}: sharded answers must equal single-process answers",
                d.code()
            );
            assert_eq!(resp.stats.remote_bases, resp.stats.executed_bases);
            let m = coord.shard_metrics();
            let speedup = t_single / t.max(1e-9);
            println!(
                "| {} | {shards} | {t:.3} | {speedup:.2}× | {} |",
                d.code(),
                m.partials_merged
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"shards\": {shards}, \"batch_s\": {t:.6}, \"single_process_s\": {t_single:.6}, \"speedup_vs_single\": {speedup:.3}, \"total_bases\": {}, \"remote_bases\": {}, \"partials_merged\": {}, \"remote_cached\": {}}}",
                d.code(),
                resp.stats.total_bases,
                resp.stats.remote_bases,
                m.partials_merged,
                m.remote_cached,
            )));
            drop(coord);
            for w in workers {
                w.shutdown();
            }
        }

        // fault recovery: the same batch over 3 healthy workers vs 3
        // healthy workers plus one that handshakes, takes its first
        // request, and dies — the fabric retries it, declares it dead,
        // re-fans its sub-slices across the survivors, and the answers
        // must still equal the single-process baseline. The delta between
        // the two rows is the price of one mid-batch worker death.
        let fault_config = crate::shard::PoolConfig {
            max_retries: 1,
            retry_base: std::time::Duration::from_millis(50),
            retry_cap: std::time::Duration::from_millis(200),
            ..crate::shard::PoolConfig::default()
        };
        for killed in [0usize, 1] {
            let workers: Vec<ShardWorker> = (0..3)
                .map(|_| {
                    ShardWorker::bind(
                        d.generate(scale),
                        "127.0.0.1:0",
                        WorkerConfig {
                            threads,
                            fused: true,
                            cache_bytes: 64 << 20,
                            persist: None,
                            slice_pin: None,
                        },
                    )
                    .expect("bind shard worker")
                })
                .collect();
            let mut addrs: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
            if killed == 1 {
                addrs.push(spawn_dying_worker(d.generate(scale).fingerprint()));
            }
            let planner = QueryPlanner::new(Policy::Naive, true, threads);
            let flat: Vec<Vec<String>> = addrs.iter().map(|a| vec![a.clone()]).collect();
            let mut coord = ShardCoordinator::connect_with(
                d.generate(scale),
                &flat,
                planner,
                64 << 20,
                fault_config,
            )?;
            let (resp, t) = time(|| coord.call(&batch).expect("fault-recovery batch"));
            assert_eq!(
                resp.results,
                single.results,
                "{}: counts must survive {killed} mid-batch worker death(s)",
                d.code()
            );
            let m = coord.shard_metrics();
            assert_eq!(
                m.worker_failures > 0,
                killed > 0,
                "{}: failures counted iff a worker died: {m:?}",
                d.code()
            );
            println!(
                "| {} | 3+{killed} dying | {t:.3} | {:.2}× | {} |",
                d.code(),
                t_single / t.max(1e-9),
                m.partials_merged
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"shards\": 3, \"killed_workers\": {killed}, \"batch_s\": {t:.6}, \"single_process_s\": {t_single:.6}, \"worker_failures\": {}, \"retries\": {}, \"refanned\": {}, \"probes\": {}}}",
                d.code(),
                m.worker_failures,
                m.retries,
                m.refanned,
                m.probes,
            )));
            drop(coord);
            for w in workers {
                w.shutdown();
            }
        }

        // replication: the same batch over 2 groups × 2 replicas vs 4
        // unreplicated seats, healthy vs one seat dying mid-batch. A
        // replicated topology must absorb the death by failing over
        // inside the group — never by re-fanning across groups — while
        // the flat topology shows the re-fan path for contrast; the row
        // delta is what one replica's death costs under each regime.
        for (replicated, killed) in [(false, 0usize), (false, 1), (true, 0), (true, 1)] {
            let workers: Vec<ShardWorker> = (0..4 - killed)
                .map(|_| {
                    ShardWorker::bind(
                        d.generate(scale),
                        "127.0.0.1:0",
                        WorkerConfig {
                            threads,
                            fused: true,
                            cache_bytes: 64 << 20,
                            persist: None,
                            slice_pin: None,
                        },
                    )
                    .expect("bind shard worker")
                })
                .collect();
            let mut addrs: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
            if killed == 1 {
                // the dying seat lands in the second group (replicated)
                // or as the fourth flat seat
                addrs.push(spawn_dying_worker(d.generate(scale).fingerprint()));
            }
            let groups: Vec<Vec<String>> = if replicated {
                vec![addrs[..2].to_vec(), addrs[2..].to_vec()]
            } else {
                addrs.iter().map(|a| vec![a.clone()]).collect()
            };
            let topology = if replicated { "2x2" } else { "flat4" };
            let planner = QueryPlanner::new(Policy::Naive, true, threads);
            let mut coord = ShardCoordinator::connect_with(
                d.generate(scale),
                &groups,
                planner,
                64 << 20,
                fault_config,
            )?;
            let (resp, t) = time(|| coord.call(&batch).expect("replication batch"));
            assert_eq!(
                resp.results,
                single.results,
                "{}: {topology} counts must survive {killed} replica death(s)",
                d.code()
            );
            let m = coord.shard_metrics();
            if replicated {
                assert_eq!(
                    m.refanned, 0,
                    "{}: replicated groups never re-fan across groups: {m:?}",
                    d.code()
                );
                assert_eq!(
                    m.failovers > 0,
                    killed > 0,
                    "{}: failovers counted iff a replica died: {m:?}",
                    d.code()
                );
            } else {
                assert_eq!(
                    m.refanned > 0,
                    killed > 0,
                    "{}: flat topologies re-fan iff a worker died: {m:?}",
                    d.code()
                );
            }
            println!(
                "| {} | {topology}+{killed} dying | {t:.3} | {:.2}× | {} |",
                d.code(),
                t_single / t.max(1e-9),
                m.partials_merged
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"topology\": \"{topology}\", \"killed_replicas\": {killed}, \"batch_s\": {t:.6}, \"single_process_s\": {t_single:.6}, \"worker_failures\": {}, \"failovers\": {}, \"hedges\": {}, \"refanned\": {}, \"retries\": {}}}",
                d.code(),
                m.worker_failures,
                m.failovers,
                m.hedges,
                m.refanned,
                m.retries,
            )));
            drop(coord);
            for w in workers {
                w.shutdown();
            }
        }
    }
    let json = format!(
        "{{\n  \"experiment\": \"shard_first_level_scaling\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    write_rows_json(out, &json, rows.len())
}

/// Bench-only misbehaving worker: completes the v-current handshake,
/// reads its first EXEC, then drops the connection — a deterministic
/// stand-in for a worker process dying mid-batch. Accepts a handful of
/// connections so the coordinator's retries also reach a corpse; the
/// listener thread is detached (it parks after its last accept and dies
/// with the process).
fn spawn_dying_worker(fingerprint: crate::graph::GraphFingerprint) -> String {
    use crate::shard::proto::{self, Msg};
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind dying worker");
    let addr = listener.local_addr().expect("dying worker addr").to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming().take(4) {
            let Ok(mut s) = conn else { continue };
            let Ok(Msg::Hello { .. }) = proto::read_msg(&mut s) else { continue };
            let welcome = Msg::Welcome { fingerprint, threads: 1 };
            if proto::write_msg(&mut s, &welcome).is_err() {
                continue;
            }
            let _ = proto::read_msg(&mut s); // first request: accepted, never answered
        }
    });
    addr
}

/// A9: durable result store — cold vs warm-restart vs replay-heavy.
pub fn ablation_persist(scale: Scale, threads: usize) -> Result<()> {
    let out = std::env::var("MM_PERSIST_JSON").unwrap_or_else(|_| "BENCH_persist.json".into());
    ablation_persist_to(scale, threads, std::path::Path::new(&out))
}

/// [`ablation_persist`] with an explicit JSON output path (see
/// [`ablation_fused_to`] for why tests avoid the env override).
///
/// Three restart regimes per dataset, one persist directory each:
/// * **cold** — a fresh directory: every base executes, the WAL absorbs
///   one record per insert, graceful shutdown compacts to a snapshot;
/// * **warm-restart** — a new service (a "new process") over the same
///   graph and directory: recovery loads the snapshot, and the same batch
///   must execute **zero** bases (asserted) with answers identical to the
///   cold run's;
/// * **replay-heavy** — several distinct batches persisted with snapshot
///   compaction disabled, then a restart that must rebuild the store by
///   replaying the whole WAL (asserted: no snapshot contributed) and
///   still serve the first batch warm.
pub fn ablation_persist_to(scale: Scale, threads: usize, out: &std::path::Path) -> Result<()> {
    use crate::service::{PersistConfig, PersistOpts, Service, ServiceConfig};
    println!("\n### A9 — durable result store (restart regimes, s)\n");
    println!("| graph | phase | recovery | batch | restored | snapshot entries | wal records |");
    println!("|-------|-------|----------|-------|----------|------------------|-------------|");
    let batch_a = ["motifs:4", "match:cycle4,diamond-vi"];
    let extra_batches: [&[&str]; 2] =
        [&["match:cycle4,tailed,star4-vi", "cliques:4"], &["motifs:3"]];
    let mut rows: Vec<String> = Vec::new();
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let dir = std::env::temp_dir().join(format!("mm_bench_persist_{}", d.code()));
        let _ = std::fs::remove_dir_all(&dir);
        let config = |opts: PersistOpts| ServiceConfig {
            workers: 2,
            threads,
            policy: Policy::Naive, // deterministic alternative sets
            fused: true,
            cache_bytes: 64 << 20,
            persist: Some(PersistConfig {
                dir: dir.clone(),
                opts,
            }),
            delta_budget: crate::service::delta::DEFAULT_DELTA_BUDGET,
        };

        // cold: fresh directory, graceful shutdown compacts
        let svc = Service::try_start(d.generate(scale), config(PersistOpts::default()))?;
        let (cold, t_cold) = time(|| svc.call(&batch_a).expect("cold batch"));
        assert_eq!(cold.stats.cached_bases, 0, "fresh dir must start cold");
        let (_, t_shutdown) = time(|| drop(svc));

        // warm restart: snapshot recovery in a "new process"
        let (svc, t_recover) = time(|| {
            Service::try_start(d.generate(scale), config(PersistOpts::default())).expect("restart")
        });
        let rec = svc.recovery_report().expect("persistence configured");
        assert!(rec.fingerprint_matched, "same graph content must recover warm");
        assert!(rec.restored > 0);
        let (warm, t_warm) = time(|| svc.call(&batch_a).expect("warm batch"));
        assert_eq!(warm.stats.executed_bases, 0, "warm restart must execute zero bases");
        assert_eq!(cold.results, warm.results, "recovery must not change answers");
        drop(svc);

        // replay-heavy: WAL-only state (no snapshot compaction at all)
        let _ = std::fs::remove_dir_all(&dir);
        let heavy = PersistOpts {
            snapshot_every: usize::MAX,
            compact_on_drop: false,
            fsync_every: None,
        };
        let svc = Service::try_start(d.generate(scale), config(heavy))?;
        svc.call(&batch_a).expect("replay seed batch");
        for b in extra_batches {
            svc.call(b).expect("replay filler batch");
        }
        drop(svc);
        let (svc, t_replay) = time(|| {
            Service::try_start(d.generate(scale), config(heavy)).expect("replay restart")
        });
        let rec2 = svc.recovery_report().expect("persistence configured");
        assert_eq!(rec2.snapshot_entries, 0, "no snapshot was ever written");
        assert!(rec2.wal_records > 0 && rec2.fingerprint_matched);
        let (warm2, t_warm2) = time(|| svc.call(&batch_a).expect("replayed batch"));
        assert_eq!(warm2.stats.executed_bases, 0, "replayed store must serve warm");
        assert_eq!(cold.results, warm2.results);
        drop(svc);
        let _ = std::fs::remove_dir_all(&dir);

        for (phase, t_rec, t_batch, r, report) in [
            ("cold", 0.0, t_cold, &cold, None),
            ("warm-restart", t_recover, t_warm, &warm, Some(rec)),
            ("replay-heavy", t_replay, t_warm2, &warm2, Some(rec2)),
        ] {
            let s = r.stats;
            let (restored, snap, walr) =
                report.map_or((0, 0, 0), |x| (x.restored, x.snapshot_entries, x.wal_records));
            println!(
                "| {} | {phase} | {t_rec:.3} | {t_batch:.3} | {restored} | {snap} | {walr} |",
                d.code()
            );
            rows.push(with_metrics(format!(
                "    {{\"graph\": \"{}\", \"phase\": \"{phase}\", \"recovery_s\": {t_rec:.6}, \"batch_s\": {t_batch:.6}, \"shutdown_compact_s\": {t_shutdown:.6}, \"total_bases\": {}, \"executed_bases\": {}, \"restored_entries\": {restored}, \"snapshot_entries\": {snap}, \"wal_records\": {walr}}}",
                d.code(),
                s.total_bases,
                s.executed_bases,
            )));
        }
    }
    let json = format!(
        "{{\n  \"experiment\": \"persist_durable_store\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    write_rows_json(out, &json, rows.len())
}

/// Run all ablations.
pub fn run_all(scale: Scale, threads: usize) -> Result<()> {
    println!("\n## Ablations\n");
    ablation_symmetry(scale, threads)?;
    ablation_intersections()?;
    ablation_cost_model(scale, threads)?;
    ablation_incremental(scale, threads)?;
    ablation_approx(scale, threads)?;
    ablation_fused(scale, threads)?;
    ablation_kernels(scale, threads)?;
    ablation_service(scale, threads)?;
    ablation_persist(scale, threads)?;
    ablation_shard(scale, threads)?;
    ablation_incremental_service(scale, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_smoke() {
        // tiny smoke run of the cheap ablations (symmetry check asserts the
        // |Aut| relation internally)
        ablation_intersections().unwrap();
        ablation_cost_model(Scale::Tiny, 2).unwrap();
    }

    #[test]
    fn fused_ablation_smoke() {
        // asserts fused == per-pattern internally (counting, MNI and
        // enumeration modes); explicit temp output path — no env mutation
        let out = std::env::temp_dir().join("mm_bench_fused_smoke.json");
        ablation_fused_to(Scale::Tiny, 2, &out).unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("fused_vs_per_pattern"));
        assert!(body.contains("\"agg\": \"mni\""));
        assert!(body.contains("\"agg\": \"enumerate\""));
    }

    #[test]
    fn kernels_ablation_smoke() {
        // asserts counts invariant across all representation × tier
        // configurations internally; explicit temp output path
        let out = std::env::temp_dir().join("mm_bench_kernels_smoke.json");
        ablation_kernels_to(Scale::Tiny, 2, &out).unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("kernel_tiers_x_representation"));
        assert!(body.contains("relabel+hybrid+simd"));
    }

    #[test]
    fn persist_ablation_smoke() {
        // asserts warm-restart zero-execution, replay-only recovery and
        // answer equality across restarts inside
        let out = std::env::temp_dir().join("mm_bench_persist_smoke.json");
        ablation_persist_to(Scale::Tiny, 2, &out).unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("persist_durable_store"));
        assert!(body.contains("\"phase\": \"warm-restart\""));
        assert!(body.contains("\"phase\": \"replay-heavy\""));
        // every row embeds a registry snapshot as counter evidence
        assert!(body.contains("\"metrics\": {"), "{body}");
        assert!(body.contains("mm_wal_append_us"), "{body}");
        assert!(existing_measured_rows(&out), "smoke run must emit measured rows");
    }

    #[test]
    fn service_ablation_smoke() {
        // asserts warm-zero-execution and partial-reuse exactness inside
        let out = std::env::temp_dir().join("mm_bench_service_smoke.json");
        ablation_service_to(Scale::Tiny, 2, &out).unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("service_result_cache"));
        assert!(body.contains("\"batch\": \"warm\""));
        assert!(body.contains("\"batch\": \"overlap\""));
        assert!(body.contains("\"metrics\": {"), "{body}");
        assert!(body.contains("mm_planner_batches_total"), "{body}");
        assert!(existing_measured_rows(&out), "smoke run must emit measured rows");
    }

    #[test]
    fn incremental_ablation_smoke() {
        // asserts delta-patch == purge answers inside, on the identical
        // deterministic update stream; explicit temp output path
        let out = std::env::temp_dir().join("mm_bench_incremental_smoke.json");
        ablation_incremental_service_to(Scale::Tiny, 2, &out).unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("incremental_delta_maintenance"));
        assert!(body.contains("\"mode\": \"delta-patch\""));
        assert!(body.contains("\"mode\": \"purge\""));
        assert!(body.contains("\"metrics\": {"), "{body}");
        assert!(existing_measured_rows(&out), "smoke run must emit measured rows");
    }

    #[test]
    fn shard_ablation_smoke() {
        // asserts sharded == single-process answers inside, across 1/2/4
        // local workers; explicit temp output path
        let out = std::env::temp_dir().join("mm_bench_shard_smoke.json");
        ablation_shard_to(Scale::Tiny, 2, &out).unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("shard_first_level_scaling"));
        assert!(body.contains("\"shards\": 4"));
        assert!(existing_measured_rows(&out), "smoke run must emit measured rows");
    }

    #[test]
    fn placeholder_never_clobbers_measured_rows() {
        let out = std::env::temp_dir().join("mm_bench_guard.json");
        let measured = "{\n  \"rows\": [\n    {\"a\": 1}\n  ]\n}\n";
        std::fs::write(&out, measured).unwrap();
        assert!(existing_measured_rows(&out));
        // a placeholder write must refuse and keep the measured content
        write_rows_json(&out, "{\n  \"rows\": []\n}\n", 0).unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), measured);
        // a measured write replaces it
        let newer = "{\n  \"rows\": [\n    {\"b\": 2}\n  ]\n}\n";
        write_rows_json(&out, newer, 1).unwrap();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), newer);
        // placeholders may land on placeholder (or missing) files
        let fresh = std::env::temp_dir().join("mm_bench_guard_fresh.json");
        let _ = std::fs::remove_file(&fresh);
        write_rows_json(&fresh, "{\n  \"rows\": []\n}\n", 0).unwrap();
        assert!(!existing_measured_rows(&fresh));
        write_rows_json(&fresh, "{\n  \"rows\": []\n}\n", 0).unwrap();
    }
}
