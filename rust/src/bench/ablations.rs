//! Ablation benchmarks for the design choices DESIGN.md calls out:
//!
//! * **A1 — symmetry breaking**: Grochow–Kellis ordering constraints vs
//!   exploring all automorphic images (Peregrine's key substrate property;
//!   without it the E/I-vs-V/I cost asymmetries that morphing exploits
//!   change magnitude).
//! * **A2 — set-intersection strategy**: galloping vs forced linear merge
//!   on the skewed adjacency lists of power-law graphs.
//! * **A3 — cost-model fidelity**: does the §4.1 cost model *rank* patterns
//!   the way measured match times rank them? (That ranking is all the
//!   optimizer needs — absolute values are irrelevant.)
//! * **A4 — incremental vs batch recount** on an update stream.
//! * **A5 — approximate counting + exact morphing conversion**: estimator
//!   error across sample budgets.
//! * **A6 — fused multi-pattern co-execution**: one shared-prefix trie
//!   traversal for the whole base set vs one sweep per pattern (reports
//!   wall time, first-level traversal counts and trie sharing; written to
//!   `BENCH_fused.json`, path overridable via `MM_FUSED_JSON`).

use crate::apps;
use crate::exec;
use crate::graph::generators::{Dataset, Scale};
use crate::graph::{DynGraph, GraphStats};
use crate::morph::{self, Policy};
use crate::pattern::{catalog, Pattern};
use crate::plan::cost::{estimate, CostParams};
use crate::plan::fused::FusedPlan;
use crate::plan::Plan;
use crate::util::timer::Timer;
use anyhow::Result;

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// A1: symmetry breaking on/off.
pub fn ablation_symmetry(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A1 — symmetry breaking (match times, s)\n");
    println!("| graph | pattern | with | without | speedup |");
    println!("|-------|---------|------|---------|---------|");
    for d in [Dataset::MicoSim, Dataset::OrkutSim] {
        let g = d.generate(scale);
        for (name, p) in [
            ("triangle", catalog::triangle()),
            ("cycle4^E", catalog::cycle(4)),
            ("cycle4^V", catalog::cycle(4).vertex_induced()),
            ("clique4", catalog::clique(4)),
        ] {
            let with_plan = Plan::compile(&p);
            let without_plan = Plan::compile_opts(&p, false);
            let (c_with, t_with) =
                time(|| exec::parallel::par_count_matches(&g, &with_plan, threads));
            let (c_without, t_without) =
                time(|| exec::parallel::par_count_matches(&g, &without_plan, threads));
            assert_eq!(c_with * with_plan.aut_count as u64, c_without);
            println!(
                "| {} | {name} | {t_with:.3} | {t_without:.3} | {:.2}× |",
                d.code(),
                t_without / t_with.max(1e-9)
            );
        }
    }
    Ok(())
}

/// A2: galloping vs linear intersections (micro, synthetic skew).
pub fn ablation_intersections() -> Result<()> {
    println!("\n### A2 — intersection kernels (ns/op, synthetic skew)\n");
    println!("| |small| | |large| | galloping | linear |");
    println!("|---------|---------|-----------|--------|");
    let mut rng = crate::util::rng::Rng::new(0xA2);
    for (ns, nl) in [(16usize, 200_000usize), (256, 100_000), (4096, 65536)] {
        let mut small: Vec<u32> = (0..ns).map(|_| rng.below(1_000_000) as u32).collect();
        let mut large: Vec<u32> = (0..nl).map(|_| rng.below(1_000_000) as u32).collect();
        small.sort_unstable();
        small.dedup();
        large.sort_unstable();
        large.dedup();
        let mut out = Vec::new();
        let reps = 2000;
        let (_, t_gallop) = time(|| {
            for _ in 0..reps {
                exec::intersect::intersect_into(&small, &large, &mut out);
                std::hint::black_box(&out);
            }
        });
        // forced linear merge: same sizes, use the non-galloping path by
        // intersecting two equal-ish lists after slicing large
        let (_, t_linear) = time(|| {
            for _ in 0..reps {
                linear_intersect(&small, &large, &mut out);
                std::hint::black_box(&out);
            }
        });
        println!(
            "| {} | {} | {:.0} | {:.0} |",
            small.len(),
            large.len(),
            t_gallop / reps as f64 * 1e9,
            t_linear / reps as f64 * 1e9
        );
    }
    Ok(())
}

fn linear_intersect(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// A3: cost-model ranking fidelity (Spearman footrule vs measured times).
pub fn ablation_cost_model(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A3 — cost-model ranking vs measured match times\n");
    println!("| graph | pattern | predicted rank | measured rank | measured (s) |");
    println!("|-------|---------|----------------|---------------|--------------|");
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let g = d.generate(scale);
        let stats = GraphStats::compute(&g, 2000, 3);
        let pats = [
            ("triangle", catalog::triangle()),
            ("cycle4^E", catalog::cycle(4)),
            ("cycle4^V", catalog::cycle(4).vertex_induced()),
            ("diamond^E", catalog::diamond()),
            ("clique4", catalog::clique(4)),
            ("path4^E", catalog::path(4)),
        ];
        let mut rows: Vec<(usize, f64, f64)> = pats
            .iter()
            .enumerate()
            .map(|(i, (_, p))| {
                let plan = Plan::compile(p);
                let pred = estimate(&plan, &stats, &CostParams::counting());
                let (_, secs) = time(|| exec::parallel::par_count_matches(&g, &plan, threads));
                (i, pred, secs)
            })
            .collect();
        let rank = |v: &[(usize, f64, f64)], key: fn(&(usize, f64, f64)) -> f64| {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| key(&v[a]).partial_cmp(&key(&v[b])).unwrap());
            let mut r = vec![0usize; v.len()];
            for (rankpos, &i) in idx.iter().enumerate() {
                r[i] = rankpos;
            }
            r
        };
        let pred_rank = rank(&rows, |x| x.1);
        let meas_rank = rank(&rows, |x| x.2);
        let mut footrule = 0usize;
        for i in 0..rows.len() {
            footrule += pred_rank[i].abs_diff(meas_rank[i]);
        }
        rows.sort_by_key(|&(i, _, _)| i);
        for (i, (name, _)) in pats.iter().enumerate() {
            println!(
                "| {} | {name} | {} | {} | {:.3} |",
                d.code(),
                pred_rank[i],
                meas_rank[i],
                rows[i].2
            );
        }
        println!("| {} | *footrule distance* | | {footrule} | |", d.code());
    }
    Ok(())
}

/// A4: incremental maintenance vs batch recount over an update stream.
pub fn ablation_incremental(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A4 — incremental vs batch recount (4-motifs)\n");
    let g = Dataset::MicoSim.generate(scale);
    let updates = 20usize;
    let mut rng = crate::util::rng::Rng::new(0xA4);
    let n = g.num_vertices();

    let (mut inc, t_init) = time(|| {
        apps::IncrementalMotifCounter::new(DynGraph::from_data_graph(&g), 4, threads)
    });
    let (_, t_stream) = time(|| {
        for _ in 0..updates {
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            if u != v {
                inc.insert_edge(u, v);
            }
        }
    });
    // batch recount once for comparison
    let snapshot = inc.graph().to_data_graph("ablation");
    let (_, t_batch) = time(|| apps::count_motifs(&snapshot, 4, Policy::Naive, threads));
    println!("| init (batch) | {updates} updates (incremental) | one batch recount |");
    println!("|--------------|-------------------------------|-------------------|");
    println!(
        "| {t_init:.3}s | {t_stream:.3}s ({:.1} ms/update) | {t_batch:.3}s |",
        1e3 * t_stream / updates as f64
    );
    println!(
        "\nper-update incremental cost is {:.0}× cheaper than a recount",
        t_batch / (t_stream / updates as f64).max(1e-9)
    );
    Ok(())
}

/// A5: approximate counting error vs sample budget (+ exact conversion).
pub fn ablation_approx(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A5 — approximate counting (edge-anchored sampling)\n");
    let g = Dataset::MicoSim.generate(scale);
    let exact = apps::count_motifs(&g, 4, Policy::Naive, threads);
    println!("| samples | mean relative error (motifs ≥ 100 occurrences) |");
    println!("|---------|--------------------------------------------------|");
    for frac in [0.01f64, 0.05, 0.25] {
        let samples = ((g.num_edges() as f64 * frac) as usize).max(10);
        let approx = apps::approx_motifs(&g, 4, samples, 0x55);
        let mut errs = Vec::new();
        for (p, c) in &exact.counts {
            if *c >= 100 {
                let e = approx.get(p).unwrap();
                errs.push((e - *c as f64).abs() / *c as f64);
            }
        }
        let mean = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        println!("| {samples} ({:.0}% of edges) | {mean:.3} |", frac * 100.0);
    }
    Ok(())
}

/// A6: fused multi-pattern co-execution vs per-pattern sweeps.
///
/// Matches the whole base pattern set through the fused plan trie in one
/// traversal and compares against one `par_count_matches` sweep per
/// pattern. Counts are asserted equal; the fused path must do strictly
/// fewer first-level traversals. Results are appended to a JSON report
/// (`BENCH_fused.json`, or `MM_FUSED_JSON` if set).
pub fn ablation_fused(scale: Scale, threads: usize) -> Result<()> {
    println!("\n### A6 — fused co-execution vs per-pattern sweeps\n");
    println!("| graph | base set | per-pattern (s) | fused (s) | speedup | L0 sweeps | trie nodes / plan levels |");
    println!("|-------|----------|-----------------|-----------|---------|-----------|--------------------------|");
    let mut rows: Vec<String> = Vec::new();
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let g = d.generate(scale);
        let sets: [(&str, Vec<Pattern>); 2] = [
            (
                "4-motif naive base",
                morph::plan_queries(
                    &catalog::motifs_vertex_induced(4),
                    Policy::Naive,
                    None,
                    &CostParams::counting(),
                )
                .base,
            ),
            ("4-motif V/I set", catalog::motifs_vertex_induced(4)),
        ];
        for (name, base) in sets {
            let plans: Vec<Plan> = base.iter().map(Plan::compile).collect();
            let fused = FusedPlan::build(&base, None, &CostParams::counting());
            let (per, t_per) = time(|| {
                plans
                    .iter()
                    .map(|p| exec::parallel::par_count_matches(&g, p, threads))
                    .collect::<Vec<u64>>()
            });
            let (fu, t_fused) =
                time(|| exec::fused::fused_count_matches(&g, &fused, threads));
            assert_eq!(per, fu, "{name}/{}: fused counts must equal per-pattern", d.code());
            let sweeps_per = plans.len();
            let sweeps_fused = fused.first_level_traversals();
            assert!(
                sweeps_fused < sweeps_per,
                "fused must do strictly fewer first-level traversals ({sweeps_fused} vs {sweeps_per})"
            );
            let speedup = t_per / t_fused.max(1e-9);
            println!(
                "| {} | {name} | {t_per:.3} | {t_fused:.3} | {speedup:.2}× | {sweeps_per}→{sweeps_fused} | {}/{} |",
                d.code(),
                fused.nodes.len(),
                fused.total_plan_levels(),
            );
            rows.push(format!(
                "    {{\"graph\": \"{}\", \"set\": \"{name}\", \"patterns\": {}, \"per_pattern_s\": {t_per:.6}, \"fused_s\": {t_fused:.6}, \"speedup\": {speedup:.3}, \"first_level_sweeps_per_pattern\": {sweeps_per}, \"first_level_sweeps_fused\": {sweeps_fused}, \"trie_nodes\": {}, \"plan_levels\": {}}}",
                d.code(),
                base.len(),
                fused.nodes.len(),
                fused.total_plan_levels(),
            ));
        }
    }
    let json = format!(
        "{{\n  \"experiment\": \"fused_vs_per_pattern\",\n  \"scale\": \"{scale:?}\",\n  \"threads\": {threads},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let out = std::env::var("MM_FUSED_JSON").unwrap_or_else(|_| "BENCH_fused.json".into());
    std::fs::write(&out, json)?;
    println!("\nwrote {out}");
    Ok(())
}

/// Run all ablations.
pub fn run_all(scale: Scale, threads: usize) -> Result<()> {
    println!("\n## Ablations\n");
    ablation_symmetry(scale, threads)?;
    ablation_intersections()?;
    ablation_cost_model(scale, threads)?;
    ablation_incremental(scale, threads)?;
    ablation_approx(scale, threads)?;
    ablation_fused(scale, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_smoke() {
        // tiny smoke run of the cheap ablations (symmetry check asserts the
        // |Aut| relation internally)
        ablation_intersections().unwrap();
        ablation_cost_model(Scale::Tiny, 2).unwrap();
    }

    #[test]
    fn fused_ablation_smoke() {
        // asserts fused == per-pattern internally; JSON goes to a temp path
        let out = std::env::temp_dir().join("mm_bench_fused_smoke.json");
        std::env::set_var("MM_FUSED_JSON", &out);
        let r = ablation_fused(Scale::Tiny, 2);
        std::env::remove_var("MM_FUSED_JSON");
        r.unwrap();
        let body = std::fs::read_to_string(&out).unwrap();
        assert!(body.contains("fused_vs_per_pattern"));
    }
}
