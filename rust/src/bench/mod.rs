//! Reproduction harness: regenerates every table and figure of the paper's
//! evaluation (§4) on the synthetic dataset stand-ins.
//!
//! | experiment | paper artifact | function |
//! |------------|----------------|----------|
//! | `table1`   | Table 1 — E/I vs V/I match times | [`table1`] |
//! | `table2`   | Table 2 — dataset statistics      | [`table2`] |
//! | `table3`   | Table 3 — No/Naïve/Cost PMR grid  | [`table3`] |
//! | `table4`   | Table 4 — chosen alternative sets | [`table4`] |
//! | `fig2`     | Fig. 2 — match vs aggregation     | [`fig2`] |
//! | `fig5`     | Fig. 4/5 — morphing equations     | [`fig5`] |
//! | `fused`    | A6 — fused co-execution ablation  | [`ablations::ablation_fused`] |
//! | `kernels`  | A7 — kernel tiers × representation | [`ablations::ablation_kernels`] |
//! | `service`  | A8 — service result cache (cold/warm/overlap) | [`ablations::ablation_service`] |
//! | `persist`  | A9 — durable store (cold/warm-restart/replay) | [`ablations::ablation_persist`] |
//! | `shard`    | A10 — first-level sharding (1/2/4 workers) + fault recovery (0 vs 1 mid-batch kill) | [`ablations::ablation_shard`] |
//! | `incremental` | A11 — delta-morphing maintenance (delta-patch vs purge-and-recompute) | [`ablations::ablation_incremental_service`] |
//!
//! Reports are printed as markdown; EXPERIMENTS.md records a run.

pub mod ablations;

use crate::apps;
use crate::graph::generators::{Dataset, Scale};
use crate::graph::{DataGraph, GraphStats};
use crate::morph::{self, Policy};
use crate::pattern::{catalog, Pattern};
use crate::plan::cost::CostParams;
use crate::util::timer::Timer;
use anyhow::{bail, Result};

/// FSM support thresholds per dataset, scaled from the paper's
/// (4000 / 23000 / 300000 on the full graphs) proportionally to vertex
/// count so the frequent-pattern structure is comparable.
fn fsm_support(d: Dataset, g: &DataGraph) -> u64 {
    let per_vertex = match d {
        Dataset::MicoSim => 4000.0 / 100_000.0,
        Dataset::PatentsSim => 23_000.0 / 3_700_000.0,
        Dataset::YoutubeSim => 300_000.0 / 6_900_000.0,
        Dataset::OrkutSim => 0.0,
    };
    ((g.num_vertices() as f64 * per_vertex).round() as u64).max(2)
}

fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Run one experiment by name (`all` runs everything).
pub fn run_experiment(exp: &str, scale: Scale, threads: usize) -> Result<()> {
    match exp {
        "table1" => table1(scale, threads),
        "table2" => table2(scale),
        "table3" => table3(scale, threads),
        "table4" => table4(scale),
        "fig2" => fig2(scale, threads),
        "fig5" => fig5(scale, threads),
        "fused" => ablations::ablation_fused(scale, threads),
        "kernels" => ablations::ablation_kernels(scale, threads),
        "service" => ablations::ablation_service(scale, threads),
        "persist" => ablations::ablation_persist(scale, threads),
        "shard" => ablations::ablation_shard(scale, threads),
        "incremental" => ablations::ablation_incremental_service(scale, threads),
        "ablations" => ablations::run_all(scale, threads),
        "all" => {
            table2(scale)?;
            table1(scale, threads)?;
            fig2(scale, threads)?;
            fig5(scale, threads)?;
            table4(scale)?;
            table3(scale, threads)?;
            ablations::run_all(scale, threads)
        }
        other => bail!(
            "unknown experiment {other:?} (table1|table2|table3|table4|fig2|fig5|fused|kernels|service|persist|shard|incremental|ablations|all)"
        ),
    }
}

/// Table 1: execution times for matching the 4-cycle, chordal 4-cycle and
/// 5-cycle, edge-induced vs vertex-induced, on Mico and YouTube stand-ins.
pub fn table1(scale: Scale, threads: usize) -> Result<()> {
    println!("\n## Table 1 — edge- vs vertex-induced matching times (s)\n");
    println!("| graph | C4^E | C4^V | chordal^E | chordal^V | C5^E | C5^V |");
    println!("|-------|------|------|-----------|-----------|------|------|");
    let pats = [
        catalog::cycle(4),
        catalog::cycle(4).vertex_induced(),
        catalog::diamond(),
        catalog::diamond().vertex_induced(),
        catalog::cycle(5),
        catalog::cycle(5).vertex_induced(),
    ];
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let g = d.generate(scale);
        let mut row = format!("| {} ", d.code());
        for p in &pats {
            let (_, secs) = time(|| {
                apps::match_patterns(&g, std::slice::from_ref(p), Policy::Off, threads)
            });
            row.push_str(&format!("| {secs:.3} "));
        }
        println!("{row}|");
    }
    Ok(())
}

/// Table 2: dataset statistics of the synthetic stand-ins.
pub fn table2(scale: Scale) -> Result<()> {
    println!("\n## Table 2 — datasets ({scale:?} scale)\n");
    println!("| G | |V(G)| | |E(G)| | |L(G)| | max deg | avg deg |");
    println!("|---|--------|--------|--------|---------|---------|");
    for d in Dataset::all() {
        let g = d.generate(scale);
        let s = GraphStats::compute(&g, 2000, 1);
        println!(
            "| ({}) {} | {} | {} | {} | {} | {:.0} |",
            d.code(),
            g.name(),
            s.num_vertices,
            s.num_edges,
            if g.num_labels() > 0 {
                g.num_labels().to_string()
            } else {
                "—".into()
            },
            s.max_degree,
            s.avg_degree,
        );
    }
    Ok(())
}

/// The Table 3 application grid.
pub fn table3_apps() -> Vec<(&'static str, Table3App)> {
    vec![
        ("3-MC", Table3App::Motifs(3)),
        ("4-MC", Table3App::Motifs(4)),
        ("p1^V", Table3App::Match(vec![catalog::paper_pattern(1).vertex_induced()])),
        ("p2^V", Table3App::Match(vec![catalog::paper_pattern(2).vertex_induced()])),
        ("p3^V", Table3App::Match(vec![catalog::paper_pattern(3).vertex_induced()])),
        ("p5^V", Table3App::Match(vec![catalog::paper_pattern(5).vertex_induced()])),
        ("p6^V", Table3App::Match(vec![catalog::paper_pattern(6).vertex_induced()])),
        ("p7^V", Table3App::Match(vec![catalog::paper_pattern(7).vertex_induced()])),
        ("p2^E", Table3App::Match(vec![catalog::paper_pattern(2)])),
        (
            "{p2^E,p3^E}",
            Table3App::Match(vec![catalog::paper_pattern(2), catalog::paper_pattern(3)]),
        ),
        (
            "{p5^V,p6^V}",
            Table3App::Match(vec![
                catalog::paper_pattern(5).vertex_induced(),
                catalog::paper_pattern(6).vertex_induced(),
            ]),
        ),
        ("3-FSM", Table3App::Fsm(3)),
    ]
}

/// One Table 3 application.
#[derive(Clone)]
pub enum Table3App {
    Motifs(usize),
    Match(Vec<Pattern>),
    Fsm(usize),
}

/// Run one Table 3 cell; returns (elapsed seconds, checksum of results).
pub fn run_table3_cell(
    app: &Table3App,
    g: &DataGraph,
    d: Dataset,
    policy: Policy,
    threads: usize,
) -> Option<(f64, u64)> {
    match app {
        Table3App::Motifs(size) => {
            let (r, secs) = time(|| apps::count_motifs(g, *size, policy, threads));
            Some((secs, r.counts.iter().map(|(_, c)| c).sum()))
        }
        Table3App::Match(queries) => {
            let (r, secs) = time(|| apps::match_patterns(g, queries, policy, threads));
            Some((secs, r.counts.iter().sum()))
        }
        Table3App::Fsm(edges) => {
            if !g.is_labeled() {
                return None; // paper: no FSM on Orkut (unlabeled)
            }
            let support = fsm_support(d, g);
            let (r, secs) = time(|| {
                apps::fsm(
                    g,
                    &apps::FsmConfig {
                        max_edges: *edges,
                        support,
                        policy,
                        threads,
                        fused: true,
                    },
                )
            });
            Some((secs, r.frequent.len() as u64))
        }
    }
}

/// Table 3: the headline grid — every application × dataset × policy.
/// Asserts result equality across policies (morphing must be exact).
pub fn table3(scale: Scale, threads: usize) -> Result<()> {
    println!("\n## Table 3 — execution times (s), incl. morphing time\n");
    println!("| App | G | No PMR | Naïve PMR | Cost PMR | naïve× | cost× |");
    println!("|-----|---|--------|-----------|----------|--------|-------|");
    for (name, app) in table3_apps() {
        for d in Dataset::all() {
            // the paper also omits p7^V on Orkut (Table 3 has no OK row for
            // it): the naïvely-morphed 5-cycle explodes on dense graphs
            if name == "p7^V" && d == Dataset::OrkutSim {
                continue;
            }
            let g = d.generate(scale);
            let Some((t_off, sum_off)) = run_table3_cell(&app, &g, d, Policy::Off, threads)
            else {
                continue;
            };
            let (t_naive, sum_naive) =
                run_table3_cell(&app, &g, d, Policy::Naive, threads).unwrap();
            let (t_cost, sum_cost) =
                run_table3_cell(&app, &g, d, Policy::CostBased, threads).unwrap();
            assert_eq!(sum_off, sum_naive, "{name}/{}: naive result mismatch", d.code());
            assert_eq!(sum_off, sum_cost, "{name}/{}: cost result mismatch", d.code());
            println!(
                "| {name} | {} | {t_off:.3} | {t_naive:.3} | {t_cost:.3} | {:.2}× | {:.2}× |",
                d.code(),
                t_off / t_naive.max(1e-9),
                t_off / t_cost.max(1e-9),
            );
        }
    }
    Ok(())
}

/// Table 4: alternative pattern sets chosen by Cost-Based PMR.
pub fn table4(scale: Scale) -> Result<()> {
    println!("\n## Table 4 — alternative pattern sets (Cost-Based PMR)\n");
    let queries: Vec<(&str, Vec<Pattern>)> = vec![
        ("p1^V", vec![catalog::paper_pattern(1).vertex_induced()]),
        ("p2^V", vec![catalog::paper_pattern(2).vertex_induced()]),
        ("p2^E", vec![catalog::paper_pattern(2)]),
        ("p3^V", vec![catalog::paper_pattern(3).vertex_induced()]),
        (
            "{p2^E,p3^E}",
            vec![catalog::paper_pattern(2), catalog::paper_pattern(3)],
        ),
    ];
    println!("| App | G | Alt. Set |");
    println!("|-----|---|----------|");
    for (name, qs) in &queries {
        for d in Dataset::all() {
            let g = d.generate(scale);
            let stats = GraphStats::compute(&g, 2000, 2);
            let plan =
                morph::plan_queries(qs, Policy::CostBased, Some(&stats), &CostParams::counting());
            let alt: Vec<String> = plan.base.iter().map(describe_short).collect();
            println!("| {name} | {} | {{{}}} |", d.code(), alt.join(", "));
        }
    }
    Ok(())
}

/// Figure 2: FSM vs MC time breakdown (matching vs aggregation).
pub fn fig2(scale: Scale, threads: usize) -> Result<()> {
    println!("\n## Figure 2 — matching vs aggregation breakdown\n");
    println!("| app | graph | total (s) | match % | aggregate/convert % |");
    println!("|-----|-------|-----------|---------|---------------------|");
    for d in [Dataset::MicoSim, Dataset::YoutubeSim] {
        let g = d.generate(scale);
        // 4-MC
        let (mc, secs) = time(|| apps::count_motifs(&g, 4, Policy::Off, threads));
        let match_t = mc.profile.get("match").as_secs_f64();
        let agg_t = mc.profile.get("convert").as_secs_f64();
        let tot = (match_t + agg_t).max(1e-12);
        println!(
            "| 4-MC | {} | {secs:.3} | {:.1} | {:.1} |",
            d.code(),
            100.0 * match_t / tot,
            100.0 * agg_t / tot
        );
        // 3-FSM
        let support = fsm_support(d, &g);
        let (fs, secs) = time(|| {
            apps::fsm(
                &g,
                &apps::FsmConfig {
                    max_edges: 3,
                    support,
                    policy: Policy::Off,
                    threads,
                    fused: true,
                },
            )
        });
        let match_t = fs.profile.get("match").as_secs_f64();
        let agg_t = fs.profile.get("aggregate").as_secs_f64()
            + fs.profile.get("convert").as_secs_f64()
            + fs.profile.get("extend").as_secs_f64();
        let tot = (match_t + agg_t).max(1e-12);
        println!(
            "| 3-FSM | {} | {secs:.3} | {:.1} | {:.1} |",
            d.code(),
            100.0 * match_t / tot,
            100.0 * agg_t / tot
        );
    }
    Ok(())
}

/// Figures 4 & 5: print the morphing equations for all 4-motifs (in the
/// paper's unique-match coefficients) and machine-check that evaluating the
/// morphed side reproduces the direct counts.
pub fn fig5(scale: Scale, threads: usize) -> Result<()> {
    println!("\n## Figures 4/5 — morphing equations for 4-motifs\n");
    for m in catalog::motifs_vertex_induced(4) {
        let e = morph::engine::naive_expr(&m);
        println!("    {}", render_unique_equation(&e));
    }
    let g = Dataset::MicoSim.generate(scale);
    let direct = apps::count_motifs(&g, 4, Policy::Off, threads);
    let morphed = apps::count_motifs(&g, 4, Policy::Naive, threads);
    println!("\nverification on {}:", g.name());
    for ((p, a), (_, b)) in direct.counts.iter().zip(&morphed.counts) {
        println!(
            "    {:<55} direct={a:>10} morphed={b:>10} {}",
            format!("{p:?}"),
            if a == b { "✓" } else { "✗ MISMATCH" }
        );
        assert_eq!(a, b);
    }
    Ok(())
}

/// Render a morph expression with the paper's unique-match coefficients
/// (map-space coefficient × |Aut(term)| / |Aut(query)|).
pub fn render_unique_equation(e: &morph::MorphExpr) -> String {
    let aut_q = crate::pattern::iso::automorphisms(&e.query).len() as i64;
    let mut s = format!("count({}) =", describe_short(&e.query));
    let mut first = true;
    for t in e.terms.values() {
        let aut_t = crate::pattern::iso::automorphisms(&t.pattern).len() as i64;
        let c = t.coefficient() * aut_t / aut_q;
        if !first {
            s.push_str(if c >= 0 { " +" } else { " -" });
        } else {
            first = false;
            if c < 0 {
                s.push_str(" -");
            }
        }
        let a = c.abs();
        if a != 1 {
            s.push_str(&format!(" {a}·"));
        } else {
            s.push(' ');
        }
        s.push_str(&describe_short(&t.pattern));
    }
    s
}

/// Short pattern name for reports (falls back to the edge list).
pub fn describe_short(p: &Pattern) -> String {
    let named: [(&str, Pattern); 13] = [
        ("wedge", catalog::path(3)),
        ("triangle", catalog::triangle()),
        ("star4", catalog::star(4)),
        ("path4", catalog::path(4)),
        ("tailedtri", catalog::tailed_triangle()),
        ("cycle4", catalog::cycle(4)),
        ("diamond", catalog::diamond()),
        ("clique4", catalog::clique(4)),
        ("cycle5", catalog::cycle(5)),
        ("house", catalog::house()),
        ("gem", catalog::gem()),
        ("clique5", catalog::clique(5)),
        ("path5", catalog::path(5)),
    ];
    for (name, q) in named {
        if p.num_vertices() == q.num_vertices() && !p.is_labeled() {
            if q.is_clique() && p.canonical_key() == q.canonical_key() {
                return name.to_string();
            }
            if p.canonical_key() == q.canonical_key() {
                return format!("{name}^E");
            }
            if p.canonical_key() == q.vertex_induced().canonical_key() {
                return format!("{name}^V");
            }
        }
    }
    p.describe()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn describe_short_names() {
        assert_eq!(describe_short(&catalog::cycle(4)), "cycle4^E");
        assert_eq!(
            describe_short(&catalog::cycle(4).vertex_induced()),
            "cycle4^V"
        );
        assert_eq!(describe_short(&catalog::clique(4)), "clique4");
    }

    #[test]
    fn unique_equation_matches_figure4() {
        // count(cycle4^E) = cycle4^V + diamond^V + 3·clique4  (PR-E2)
        let e = morph::engine::naive_expr(&catalog::cycle(4));
        let s = render_unique_equation(&e);
        assert!(s.contains("cycle4^V"), "{s}");
        assert!(s.contains("diamond^V"), "{s}");
        assert!(s.contains("3·clique4"), "{s}");
    }

    #[test]
    fn table3_cell_smoke() {
        let d = Dataset::MicoSim;
        let g = d.generate(Scale::Tiny);
        for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
            let cell = run_table3_cell(&Table3App::Motifs(3), &g, d, policy, 2);
            assert!(cell.is_some());
        }
        // FSM skipped on unlabeled orkut
        let ok = Dataset::OrkutSim;
        let go = ok.generate(Scale::Tiny);
        assert!(run_table3_cell(&Table3App::Fsm(3), &go, ok, Policy::Off, 2).is_none());
    }
}
