//! Plan cost model — paper §4.1's three factors:
//! 1. exploration-strategy nuances (set-op work, symmetry breaking),
//! 2. application-specific operation cost per match (count vs MNI),
//! 3. data-graph details (degree moments, density, label frequencies).
//!
//! The model simulates a plan level by level, tracking the expected number
//! of partial matches and the expected set-operation work to extend them.
//! It is a *relative* model: its only job is to rank alternative pattern
//! sets for the morphing optimizer, mirroring how the paper's cost-based
//! PMR picks different alternative sets per data graph.
//!
//! The same model (and the same [`GraphStats`] instance, threaded through
//! [`crate::morph::ExecOpts`]) also scores matching orders for the fused
//! set-planner, and it knows about the hybrid representation: operands
//! served by hub bitmap rows are discounted via
//! [`GraphStats::hub_edge_fraction`].

use super::Plan;
use crate::graph::GraphStats;

/// Tunable constants of the cost model (units: abstract work ≈ ns).
#[derive(Clone, Debug)]
pub struct CostParams {
    /// Cost per element touched in a sorted intersection.
    pub intersect_unit: f64,
    /// Cost per element touched in a sorted difference (anti-edge check).
    /// Differences scan the *candidate* list against the (large) adjacency
    /// list; galloping makes them more expensive per useful output than
    /// intersections (paper §1: "enforcing them using set differences can
    /// be more expensive than performing set intersections").
    pub subtract_unit: f64,
    /// Fixed cost of emitting a match to the aggregator.
    pub match_emit: f64,
    /// Per-match aggregation cost: ~0 for counting, O(pattern size) for
    /// MNI table appends, plus enumeration materialization.
    pub agg_per_match: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            intersect_unit: 1.0,
            subtract_unit: 1.6,
            match_emit: 1.0,
            agg_per_match: 0.0,
        }
    }
}

impl CostParams {
    /// Parameters for counting aggregations.
    pub fn counting() -> Self {
        Self::default()
    }

    /// Parameters for MNI-table aggregations (FSM): each match appends
    /// `n` vertices into domain tables.
    pub fn mni(pattern_size: usize) -> Self {
        CostParams {
            agg_per_match: 4.0 * pattern_size as f64,
            ..Self::default()
        }
    }

    /// Parameters for full enumeration.
    pub fn enumeration(pattern_size: usize) -> Self {
        CostParams {
            agg_per_match: pattern_size as f64,
            ..Self::default()
        }
    }
}

/// Estimated cost of executing `plan` on a graph with `stats`.
///
/// Returns abstract work units; comparable across plans on the same graph.
pub fn estimate(plan: &Plan, stats: &GraphStats, params: &CostParams) -> f64 {
    level_costs(plan, stats, params).iter().sum()
}

/// Per-level work attribution of [`estimate`]: `out[i]` is the expected
/// set-operation work at level `i`, with the match-emit + aggregation cost
/// of complete matches folded into the last level (so the vector sums to
/// [`estimate`]).
///
/// This is the **prefix-sharing term** of the fused set-planner
/// ([`super::fused`]): levels an order candidate shares with an existing
/// plan-trie prefix are executed once for the whole pattern set, so their
/// cost is subtracted from the candidate's score.
pub fn level_costs(plan: &Plan, stats: &GraphStats, params: &CostParams) -> Vec<f64> {
    let n = stats.num_vertices as f64;
    if n == 0.0 {
        return vec![0.0; plan.levels.len()];
    }
    let d = stats.avg_degree.max(1e-9);
    // Size-biased degree (Σd² / Σd): exploration reaches vertices through
    // edges, so the expected adjacency-list size at depth ≥ 1 is the
    // friend-of-friend degree, which is much larger than the average on the
    // heavy-tailed graphs the paper mines. Using `d` here systematically
    // underestimates path-shaped edge-induced plans and made the optimizer
    // morph 5-cycles it should have left alone.
    let db = (stats.deg_sq_sum / stats.deg_sum.max(1e-9)).max(d);
    // Expected size of the intersection of two adjacency lists that share a
    // common neighbor constraint. The configuration-model estimate
    // `avg_intersection` underestimates for skewed graphs where exploration
    // concentrates on hubs; blend with clustering (fraction of wedges
    // closed): |N(u) ∩ N(v)| ≈ clustering * d when u,v adjacent.
    let closed = (stats.clustering * db).max(stats.avg_intersection).max(1e-6);
    // shrink ratio per extra intersection constraint
    let shrink = (closed / db).min(1.0);

    let mut partials = 1.0; // expected partial matches before level 0
    let mut out = Vec::with_capacity(plan.levels.len());

    for (i, level) in plan.levels.iter().enumerate() {
        // candidate-set size before constraints
        let k = level.intersect.len();
        let cand = if i == 0 {
            n
        } else {
            // first adjacency list gives ~db candidates, each further
            // intersection shrinks by `shrink`
            db * shrink.powi(k.saturating_sub(1) as i32)
        };
        // label selectivity
        let label_p = level
            .label
            .map(|l| stats.label_prob(l))
            .unwrap_or(1.0)
            .max(1e-9);
        // anti-edge filters: candidates live in the joint neighborhood of
        // already-mapped vertices, where adjacency to another mapped vertex
        // is far more likely than the global density — clustered graphs
        // prune hard. Model the per-subtraction survival with half the
        // closure ratio (calibrated so 4-vertex V/I ≈ E/I as in Table 1,
        // while deep 5-vertex V/I plans show real pruning).
        let anti_keep = (1.0 - (0.5 * shrink).min(0.9))
            .powi(level.subtract.len() as i32)
            .min(1.0 - stats.edge_prob);

        // set-operation work at this level, per partial match:
        // each intersection scans ~min(list) with galloping ≈ cand·log-ish;
        // model as cand * units. Differences scan the candidate list once
        // per subtracted adjacency (binary searches): cand * subtract_unit.
        // Hub bitmaps: operands that are hub vertices are served by O(1)
        // membership rows instead of merges, so every operand beyond the
        // seeding one (and every subtraction) is discounted by the chance
        // its vertex is a hub (`hub_edge_fraction` — 0 without the hybrid
        // index, keeping the model faithful to the executing representation).
        let hub_mult = (1.0 - 0.5 * stats.hub_edge_fraction).max(0.5);
        let level_work = if i == 0 {
            n * params.intersect_unit
        } else {
            let extra_ops = (k as f64 - 1.0).max(0.0);
            let inter_work =
                (1.0 + extra_ops * hub_mult) * d.min(cand * 4.0).max(1.0) * params.intersect_unit;
            let sub_work =
                (level.subtract.len() as f64) * cand * params.subtract_unit * hub_mult;
            partials * (inter_work + sub_work)
        };
        out.push(level_work);

        // symmetry constraints halve the surviving candidates each (on
        // average, for uniform ids)
        let sym_keep = 0.5f64.powi((level.greater_than.len() + level.less_than.len()) as i32);

        let next = if i == 0 {
            n * label_p * sym_keep
        } else {
            partials * cand * label_p * anti_keep * sym_keep
        };
        partials = next.max(0.0);
    }

    // final matches emit + aggregate, attributed to the deepest level
    if let Some(last) = out.last_mut() {
        *last += partials * (params.match_emit + params.agg_per_match);
    }
    out
}

/// Convenience: estimated number of (canonical) matches of the plan's
/// pattern — the `partials` value after the last level. Used by the
/// optimizer to weigh conversion costs.
pub fn estimate_matches(plan: &Plan, stats: &GraphStats) -> f64 {
    let n = stats.num_vertices as f64;
    if n == 0.0 {
        return 0.0;
    }
    let d = stats.avg_degree.max(1e-9);
    // size-biased degree — see `estimate`
    let db = (stats.deg_sq_sum / stats.deg_sum.max(1e-9)).max(d);
    let closed = (stats.clustering * db).max(stats.avg_intersection).max(1e-6);
    let shrink = (closed / db).min(1.0);
    let mut partials = 1.0;
    for (i, level) in plan.levels.iter().enumerate() {
        let k = level.intersect.len();
        let cand = if i == 0 {
            n
        } else {
            db * shrink.powi(k.saturating_sub(1) as i32)
        };
        let label_p = level
            .label
            .map(|l| stats.label_prob(l))
            .unwrap_or(1.0)
            .max(1e-9);
        let anti_keep = (1.0 - (0.5 * shrink).min(0.9))
            .powi(level.subtract.len() as i32)
            .min(1.0 - stats.edge_prob);
        let sym_keep = 0.5f64.powi((level.greater_than.len() + level.less_than.len()) as i32);
        partials = if i == 0 {
            n * label_p * sym_keep
        } else {
            partials * cand * label_p * anti_keep * sym_keep
        };
    }
    partials
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{barabasi_albert, erdos_renyi};
    use crate::pattern::catalog;
    use crate::plan::Plan;

    fn stats(g: &crate::graph::DataGraph) -> GraphStats {
        GraphStats::compute(g, 2000, 42)
    }

    #[test]
    fn bigger_patterns_cost_more() {
        let g = erdos_renyi(2000, 10_000, 1);
        let s = stats(&g);
        let p3 = estimate(&Plan::compile(&catalog::path(3)), &s, &CostParams::counting());
        let p5 = estimate(&Plan::compile(&catalog::cycle(5)), &s, &CostParams::counting());
        assert!(p5 > p3, "5-cycle {p5} should cost more than wedge {p3}");
    }

    #[test]
    fn mni_aggregation_costs_more_than_counting() {
        let g = erdos_renyi(2000, 10_000, 2);
        let s = stats(&g);
        let plan = Plan::compile(&catalog::path(3));
        let c = estimate(&plan, &s, &CostParams::counting());
        let m = estimate(&plan, &s, &CostParams::mni(3));
        assert!(m > c);
    }

    #[test]
    fn denser_graph_costs_more() {
        let g1 = erdos_renyi(2000, 6_000, 3);
        let g2 = erdos_renyi(2000, 24_000, 3);
        let plan = Plan::compile(&catalog::cycle(4));
        let c1 = estimate(&plan, &stats(&g1), &CostParams::counting());
        let c2 = estimate(&plan, &stats(&g2), &CostParams::counting());
        assert!(c2 > c1 * 2.0, "4x density: {c1} -> {c2}");
    }

    #[test]
    fn estimated_matches_scale_with_density() {
        let g1 = erdos_renyi(1000, 3_000, 4);
        let g2 = erdos_renyi(1000, 12_000, 4);
        let plan = Plan::compile(&catalog::triangle());
        let m1 = estimate_matches(&plan, &stats(&g1));
        let m2 = estimate_matches(&plan, &stats(&g2));
        assert!(m2 > m1 * 8.0, "triangles grow ~d^3: {m1} -> {m2}");
    }

    #[test]
    fn level_costs_sum_to_estimate() {
        let g = erdos_renyi(1000, 5_000, 6);
        let s = stats(&g);
        for p in [
            catalog::triangle(),
            catalog::cycle(4).vertex_induced(),
            catalog::clique(4),
        ] {
            let plan = Plan::compile(&p);
            let lv = level_costs(&plan, &s, &CostParams::counting());
            assert_eq!(lv.len(), plan.levels.len());
            let sum: f64 = lv.iter().sum();
            let est = estimate(&plan, &s, &CostParams::counting());
            assert!((sum - est).abs() <= 1e-9 * est.max(1.0), "{sum} vs {est}");
            assert!(lv.iter().all(|&c| c >= 0.0), "{lv:?}");
        }
    }

    #[test]
    fn hub_bitmaps_discount_set_op_work() {
        // same graph with and without the hybrid index: the model must
        // price hub-served operands cheaper, and only then
        let g = barabasi_albert(3000, 8, 9);
        let with = stats(&g);
        assert!(with.hub_count > 0, "BA graph should have hub rows");
        assert!(with.hub_edge_fraction > 0.0);
        let without = stats(&g.without_hub_bitmaps());
        assert_eq!(without.hub_edge_fraction, 0.0);
        let plan = Plan::compile(&catalog::triangle());
        let c_with = estimate(&plan, &with, &CostParams::counting());
        let c_without = estimate(&plan, &without, &CostParams::counting());
        assert!(
            c_with < c_without,
            "hub discount must lower cost: {c_with} vs {c_without}"
        );
    }

    #[test]
    fn skewed_graph_raises_costs() {
        // same |V|,|E|, heavier tail -> more wedges -> more triangle work
        let er = erdos_renyi(3000, 12_000, 5);
        let ba = barabasi_albert(3000, 4, 5);
        let plan = Plan::compile(&catalog::triangle());
        let ce = estimate(&plan, &stats(&er), &CostParams::counting());
        let cb = estimate(&plan, &stats(&ba), &CostParams::counting());
        assert!(cb > ce, "BA {cb} vs ER {ce}");
    }
}
