//! Matching-plan compiler: turns a [`Pattern`] into an executable
//! exploration plan, Peregrine-style.
//!
//! A plan fixes a *matching order* over pattern vertices and, for each
//! level, the set operations that compute the candidate data vertices:
//! intersections of adjacency lists for pattern edges to already-mapped
//! vertices, set differences for anti-edges, a label filter, and
//! symmetry-breaking `<` constraints so that each unique subgraph is
//! matched exactly once (see [`symmetry`]).

pub mod cost;
pub mod fused;
pub mod symmetry;

use crate::graph::Label;
use crate::pattern::{iso, Pattern};

/// Per-level operations of a matching plan.
///
/// Equality compares the full op set (intersections, subtractions, label,
/// symmetry bounds) — two plans whose leading levels are equal can share
/// those levels' candidate computation in a fused plan trie ([`fused`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Level {
    /// Positions `j < i` (in matching order) whose mapped vertex's adjacency
    /// list must be intersected (pattern edge).
    pub intersect: Vec<usize>,
    /// Positions `j < i` whose adjacency must be subtracted (anti-edge).
    pub subtract: Vec<usize>,
    /// Required label (`None` = unlabeled pattern or any label).
    pub label: Option<Label>,
    /// Positions `j < i` with symmetry constraint `m[j] < m[i]`.
    pub greater_than: Vec<usize>,
    /// Positions `j < i` with symmetry constraint `m[j] > m[i]`.
    pub less_than: Vec<usize>,
}

/// A compiled matching plan for one pattern.
#[derive(Clone, Debug)]
pub struct Plan {
    /// The pattern this plan matches.
    pub pattern: Pattern,
    /// `order[i]` = pattern vertex explored at level `i`.
    pub order: Vec<usize>,
    /// Per-level ops, aligned with `order`.
    pub levels: Vec<Level>,
    /// |Aut(p)| — with symmetry breaking each unique subgraph yields exactly
    /// one canonical match; multiply by this to recover map counts.
    pub aut_count: usize,
}

impl Plan {
    /// Compile a plan with symmetry breaking enabled.
    pub fn compile(pattern: &Pattern) -> Plan {
        Plan::compile_opts(pattern, true)
    }

    /// Compile, optionally without symmetry breaking (then every
    /// automorphic image of a subgraph is produced — used by tests and by
    /// the MNI aggregation which needs per-position domains).
    pub fn compile_opts(pattern: &Pattern, break_symmetry: bool) -> Plan {
        Plan::compile_with_order(pattern, choose_order(pattern), break_symmetry)
    }

    /// Compile with an explicit matching order (`order[i]` = pattern vertex
    /// explored at level `i`; every prefix must stay edge-connected). The
    /// fused set-planner ([`fused`]) uses this to trade the locally-cheapest
    /// order for cross-pattern prefix sharing.
    pub fn compile_with_order(pattern: &Pattern, order: Vec<usize>, break_symmetry: bool) -> Plan {
        let conds = if break_symmetry {
            symmetry::breaking_conditions(pattern)
        } else {
            Vec::new()
        };
        let aut_count = iso::automorphisms(pattern).len();
        Plan::with_order_and_conds(pattern, order, &conds, aut_count)
    }

    /// Compile with precomputed symmetry conditions and |Aut| — both are
    /// order-independent pattern properties, so the fused set-planner can
    /// score many candidate orders of one pattern without recomputing them.
    pub(crate) fn with_order_and_conds(
        pattern: &Pattern,
        order: Vec<usize>,
        conds: &[(usize, usize)],
        aut_count: usize,
    ) -> Plan {
        assert!(pattern.is_connected(), "cannot plan a disconnected pattern");
        let n = pattern.num_vertices();
        assert_eq!(order.len(), n, "order must cover every pattern vertex");
        // pos_of[v] = level index of pattern vertex v
        let mut pos_of = vec![usize::MAX; n];
        for (i, &v) in order.iter().enumerate() {
            pos_of[v] = i;
        }

        let mut levels = Vec::with_capacity(n);
        for (i, &v) in order.iter().enumerate() {
            let mut intersect = Vec::new();
            let mut subtract = Vec::new();
            for j in 0..i {
                let u = order[j];
                if pattern.has_edge(u, v) {
                    intersect.push(j);
                }
                if pattern.has_anti_edge(u, v) {
                    subtract.push(j);
                }
            }
            // symmetry conditions (a < b) between pattern vertices: applied
            // at the later of the two levels
            let mut greater_than = Vec::new();
            let mut less_than = Vec::new();
            for &(a, b) in conds {
                // constraint: m[a] < m[b]
                if b == v && pos_of[a] < i {
                    greater_than.push(pos_of[a]);
                }
                if a == v && pos_of[b] < i {
                    less_than.push(pos_of[b]);
                }
            }
            levels.push(Level {
                intersect,
                subtract,
                label: if pattern.is_labeled() {
                    Some(pattern.label(v))
                } else {
                    None
                },
                greater_than,
                less_than,
            });
        }

        // hard error, not debug-only: a disconnected prefix would otherwise
        // surface as an opaque unwrap panic deep in the exploration kernel
        assert!(
            levels.iter().skip(1).all(|l| !l.intersect.is_empty()),
            "matching order must keep the prefix edge-connected: {pattern:?} order={order:?}"
        );

        Plan {
            pattern: pattern.clone(),
            order,
            levels,
            aut_count,
        }
    }
}

/// Choose a matching order: start from the highest-degree pattern vertex,
/// then greedily take the vertex with the most edges into the chosen prefix
/// (ties: higher pattern degree, then more anti-edges into the prefix —
/// pruning earlier is cheaper). Every prefix stays edge-connected, which the
/// executor requires.
fn choose_order(p: &Pattern) -> Vec<usize> {
    let n = p.num_vertices();
    let mut order = Vec::with_capacity(n);
    let mut in_prefix = vec![false; n];
    let first = (0..n)
        .max_by_key(|&v| (p.degree(v), p.anti(v).len()))
        .unwrap();
    order.push(first);
    in_prefix[first] = true;
    while order.len() < n {
        let next = (0..n)
            .filter(|&v| !in_prefix[v])
            .max_by_key(|&v| {
                let edges_in = order.iter().filter(|&&u| p.has_edge(u, v)).count();
                let antis_in = order.iter().filter(|&&u| p.has_anti_edge(u, v)).count();
                (edges_in, p.degree(v), antis_in)
            })
            .unwrap();
        // connectivity of the pattern guarantees edges_in ≥ 1 for some v
        order.push(next);
        in_prefix[next] = true;
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    #[test]
    fn order_is_edge_connected_prefix() {
        for i in 1..=7 {
            let p = catalog::paper_pattern(i);
            let plan = Plan::compile(&p);
            for (lvl, l) in plan.levels.iter().enumerate().skip(1) {
                assert!(
                    !l.intersect.is_empty(),
                    "p{i} level {lvl} has no edge into prefix"
                );
            }
        }
    }

    #[test]
    fn vertex_induced_plans_have_subtractions() {
        let p = catalog::cycle(4).vertex_induced();
        let plan = Plan::compile(&p);
        let subs: usize = plan.levels.iter().map(|l| l.subtract.len()).sum();
        assert_eq!(subs, 2, "C4^V has 2 anti-edges");
        let edge_plan = Plan::compile(&catalog::cycle(4));
        let esubs: usize = edge_plan.levels.iter().map(|l| l.subtract.len()).sum();
        assert_eq!(esubs, 0);
    }

    #[test]
    fn clique_plan_fully_constrained() {
        let plan = Plan::compile(&catalog::clique(4));
        assert_eq!(plan.aut_count, 24);
        // with symmetry breaking a clique is a strictly increasing chain
        let total_ord: usize = plan
            .levels
            .iter()
            .map(|l| l.greater_than.len() + l.less_than.len())
            .sum();
        assert!(total_ord >= 3, "clique needs a total order, got {total_ord}");
    }

    #[test]
    fn labels_propagate_to_levels() {
        let p = catalog::path(3).with_labels(&[7, 8, 9]);
        let plan = Plan::compile(&p);
        for (i, &v) in plan.order.iter().enumerate() {
            assert_eq!(plan.levels[i].label, Some(p.label(v)));
        }
    }

    #[test]
    fn compile_with_explicit_order() {
        let p = catalog::tailed_triangle();
        // 2 is the degree-3 vertex; [2, 0, 1, 3] keeps every prefix connected
        let plan = Plan::compile_with_order(&p, vec![2, 0, 1, 3], true);
        assert_eq!(plan.order, vec![2, 0, 1, 3]);
        for l in plan.levels.iter().skip(1) {
            assert!(!l.intersect.is_empty());
        }
        // |Aut| is a pattern property, not an order property
        assert_eq!(plan.aut_count, Plan::compile(&p).aut_count);
    }

    #[test]
    fn no_symmetry_opt_out() {
        let plan = Plan::compile_opts(&catalog::clique(3), false);
        let total_ord: usize = plan
            .levels
            .iter()
            .map(|l| l.greater_than.len() + l.less_than.len())
            .sum();
        assert_eq!(total_ord, 0);
    }
}
