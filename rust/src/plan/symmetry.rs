//! Symmetry breaking à la Grochow–Kellis [17], as used by Peregrine.
//!
//! Without it, a subgraph with `|Aut(p)|` automorphisms is discovered that
//! many times. We impose a partial order on pattern vertices such that
//! exactly one representative of each automorphism class satisfies all
//! `m[a] < m[b]` conditions (comparisons are on data-vertex IDs).
//!
//! Construction: repeatedly take the smallest vertex `v` whose orbit under
//! the current (pointwise-stabilized) automorphism group is non-trivial,
//! emit conditions `v < u` for every other `u` in that orbit, then restrict
//! the group to the stabilizer of `v`. Terminates because each step strictly
//! shrinks the group.

use crate::pattern::iso::{self, VertexMap};
use crate::pattern::Pattern;

/// Compute symmetry-breaking conditions `(a, b)` meaning `m[a] < m[b]`.
pub fn breaking_conditions(p: &Pattern) -> Vec<(usize, usize)> {
    let n = p.num_vertices();
    let mut group: Vec<VertexMap> = iso::automorphisms(p);
    let mut conds = Vec::new();
    loop {
        if group.len() <= 1 {
            break;
        }
        // orbit of each vertex under the current group
        let mut orbit_of_v: Option<(usize, Vec<usize>)> = None;
        for v in 0..n {
            let mut orbit: Vec<usize> = group.iter().map(|a| a[v]).collect();
            orbit.sort_unstable();
            orbit.dedup();
            if orbit.len() > 1 {
                orbit_of_v = Some((v, orbit));
                break;
            }
        }
        let Some((v, orbit)) = orbit_of_v else { break };
        for &u in &orbit {
            if u != v {
                conds.push((v, u));
            }
        }
        // stabilizer of v
        group.retain(|a| a[v] == v);
    }
    conds
}

/// Verify (test helper): exactly one automorphic image of any injective map
/// satisfies the conditions. Checks the defining property on the pattern's
/// own automorphism group acting on `0..n` ids.
#[cfg(test)]
fn satisfies(conds: &[(usize, usize)], m: &[usize]) -> bool {
    conds.iter().all(|&(a, b)| m[a] < m[b])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;
    use crate::util::proptest;

    /// For every pattern: among all |Aut| images m∘a of a random injective
    /// map m, exactly one satisfies the conditions.
    fn assert_canonical_unique(p: &Pattern) {
        let auts = iso::automorphisms(p);
        let conds = breaking_conditions(p);
        let n = p.num_vertices();
        // try several injective maps into a large id space
        let mut rng = crate::util::rng::Rng::new(0xABCD + n as u64);
        for _ in 0..30 {
            let ids = rng.sample_distinct(1000, n);
            let mut count = 0;
            for a in &auts {
                // image of position v is ids[a[v]]
                let m: Vec<usize> = (0..n).map(|v| ids[a[v]]).collect();
                if satisfies(&conds, &m) {
                    count += 1;
                }
            }
            assert_eq!(
                count, 1,
                "pattern {p:?}: {count} of {} automorphic images satisfy conds {conds:?}",
                auts.len()
            );
        }
    }

    #[test]
    fn canonical_unique_for_paper_patterns() {
        for i in 1..=7 {
            assert_canonical_unique(&catalog::paper_pattern(i));
        }
    }

    #[test]
    fn canonical_unique_for_vertex_induced() {
        for i in 1..=7 {
            assert_canonical_unique(&catalog::paper_pattern(i).vertex_induced());
        }
    }

    #[test]
    fn canonical_unique_for_motifs() {
        for m in catalog::motifs_vertex_induced(4) {
            assert_canonical_unique(&m);
        }
        for m in crate::pattern::gen::connected_patterns(5) {
            assert_canonical_unique(&m);
        }
    }

    #[test]
    fn asymmetric_pattern_no_conditions() {
        // a pattern with trivial automorphism group needs no conditions
        // (path with distinct labels)
        let p = catalog::path(3).with_labels(&[1, 2, 3]);
        assert!(breaking_conditions(&p).is_empty());
    }

    #[test]
    fn clique_gets_total_order() {
        let conds = breaking_conditions(&catalog::clique(4));
        // n-1 + n-2 + ... = 6 conditions for K4
        assert_eq!(conds.len(), 6);
    }

    #[test]
    fn prop_random_patterns_canonical_unique() {
        proptest::check(0x5E7, 40, |rng| {
            // random connected pattern
            let n = 3 + rng.below_usize(3);
            let mut p = Pattern::empty(n);
            // random spanning path first for connectivity
            let perm = rng.permutation(n);
            for w in perm.windows(2) {
                p.add_edge(w[0], w[1]);
            }
            for u in 0..n {
                for v in (u + 1)..n {
                    if !p.has_edge(u, v) && rng.chance(0.35) {
                        p.add_edge(u, v);
                    }
                }
            }
            assert_canonical_unique(&p);
        });
    }
}
