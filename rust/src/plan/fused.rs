//! Set-plan compiler: fuses the matching plans of a whole base pattern set
//! into a **prefix-sharing trie** so the executor matches every pattern in
//! one data-graph traversal instead of one sweep per pattern.
//!
//! Pattern morphing's base sets share substructure by construction — the
//! morphed 4-motif set shares wedge/triangle prefixes across essentially
//! every pattern — so exploring those prefixes once amortizes the dominant
//! intersection cost across the set (the inter-pattern analogue of the
//! paper's intra-query reuse; cf. the Geo query-rewrite framework in
//! PAPERS.md).
//!
//! Construction:
//! 1. For each pattern, enumerate candidate matching orders (every order
//!    whose prefixes stay edge-connected, capped per size).
//! 2. Score each candidate with [`cost::level_costs`], discounting the
//!    levels it shares with the trie built so far — the prefix-sharing
//!    term. Shared levels run once for the whole set, so a candidate pays
//!    only for its unshared suffix.
//! 3. Insert the cheapest candidate; patterns are inserted largest-first so
//!    big plans anchor the trie and smaller ones nest into their prefixes.
//!
//! Interior nodes hold one [`Level`] of set operations (shared verbatim by
//! every pattern routed through them); each pattern's plan terminates at
//! the node where its final level lives, recorded in `emit`. The trie is
//! walked by [`crate::exec::fused::FusedExecutor`].
//!
//! ```
//! use morphmine::pattern::catalog;
//! use morphmine::plan::cost::CostParams;
//! use morphmine::plan::fused::FusedPlan;
//!
//! // the 6 vertex-induced 4-motifs share wedge/triangle prefixes
//! let base = catalog::motifs_vertex_induced(4);
//! let fused = FusedPlan::build(&base, None, &CostParams::counting());
//! assert_eq!(fused.num_patterns(), 6);
//! assert_eq!(fused.first_level_traversals(), 1, "one shared level-0 sweep");
//! assert!(fused.shared_levels() > 0, "{}", fused.describe());
//! // per-pattern plans stay aligned with the input slice
//! assert_eq!(fused.plans[0].pattern.canonical_key(), base[0].canonical_key());
//! ```

use super::cost::{self, CostParams};
use super::{symmetry, Level, Plan};
use crate::graph::GraphStats;
use crate::pattern::Pattern;
use std::cmp::Reverse;

/// Cap on enumerated candidate orders per pattern. Small patterns are
/// enumerated exhaustively; for ≥7 vertices only the default (greedy)
/// order is used — at that size per-pattern cost dwarfs prefix savings.
fn order_cap(n: usize) -> usize {
    match n {
        0..=5 => 128,
        6 => 48,
        _ => 1,
    }
}

/// One node of the fused plan trie: a level of set operations shared by
/// every pattern whose chosen plan routes through it.
#[derive(Clone, Debug)]
pub struct FusedNode {
    /// Set operations of this level (identical for all sharing patterns).
    pub level: Level,
    /// Nodes of the next level reached from this one.
    pub children: Vec<usize>,
    /// Patterns (indices into [`FusedPlan::plans`]) whose plan's final
    /// level is this node — a full match of that pattern is complete here.
    pub emit: Vec<usize>,
}

/// A fused multi-pattern plan: per-pattern [`Plan`]s plus the shared trie.
#[derive(Clone, Debug)]
pub struct FusedPlan {
    /// Per-pattern plans, aligned with the input pattern slice.
    pub plans: Vec<Plan>,
    /// Flat node storage; `roots` and `children` index into it.
    pub nodes: Vec<FusedNode>,
    /// Depth-0 nodes — one per distinct level-0 op set, so a single root
    /// (= a single first-level sweep) for unlabeled pattern sets.
    pub roots: Vec<usize>,
}

impl FusedPlan {
    /// Build a fused plan for `patterns`. `stats` steers the order scoring
    /// when available; without them a [`GraphStats::synthetic`] shape is
    /// used, so fusing is independent of the morphing policy.
    pub fn build(
        patterns: &[Pattern],
        stats: Option<&GraphStats>,
        params: &CostParams,
    ) -> FusedPlan {
        let synthetic;
        let stats = match stats {
            Some(s) => s,
            None => {
                synthetic = GraphStats::synthetic();
                &synthetic
            }
        };
        let mut fused = FusedPlan {
            plans: Vec::new(),
            nodes: Vec::new(),
            roots: Vec::new(),
        };
        let mut chosen: Vec<Option<Plan>> = vec![None; patterns.len()];
        // biggest patterns first: their long plans anchor the trie
        let mut insert_order: Vec<usize> = (0..patterns.len()).collect();
        insert_order.sort_by_key(|&i| {
            (
                Reverse(patterns[i].num_vertices()),
                patterns[i].canonical_key(),
            )
        });
        for &i in &insert_order {
            let mut best: Option<(f64, Plan)> = None;
            for plan in candidate_plans(&patterns[i]) {
                let costs = cost::level_costs(&plan, stats, params);
                let total: f64 = costs.iter().sum();
                let shared = fused.shared_prefix_len(&plan.levels);
                let saved: f64 = costs[..shared].iter().sum();
                let score = total - saved;
                let better = match &best {
                    None => true,
                    Some((b, _)) => score < *b,
                };
                if better {
                    best = Some((score, plan));
                }
            }
            let (_, plan) = best.expect("at least the default-order candidate");
            fused.insert(i, &plan);
            chosen[i] = Some(plan);
        }
        fused.plans = chosen
            .into_iter()
            .map(|p| p.expect("every pattern planned"))
            .collect();
        fused
    }

    /// [`FusedPlan::build`] over the subset of `patterns` selected by
    /// `keep` (aligned bools). Returns the fused plan plus the **original
    /// indices** of the selected patterns, aligned with the plan's `plans`
    /// (and hence with `aggregate_patterns_fused` values).
    ///
    /// This is how cached bases drop out of the trie: the service planner
    /// ([`crate::service`]) masks out every base its result store already
    /// holds, fuse-executes only the missing suffix set, and maps the
    /// values back through the returned index list.
    pub fn build_for_subset(
        patterns: &[Pattern],
        keep: &[bool],
        stats: Option<&GraphStats>,
        params: &CostParams,
    ) -> (FusedPlan, Vec<usize>) {
        assert_eq!(
            patterns.len(),
            keep.len(),
            "keep mask must align with the pattern slice"
        );
        let selected: Vec<usize> = (0..patterns.len()).filter(|&i| keep[i]).collect();
        let subset: Vec<Pattern> = selected.iter().map(|&i| patterns[i].clone()).collect();
        (FusedPlan::build(&subset, stats, params), selected)
    }

    /// Longest trie prefix whose level ops match `levels` exactly.
    fn shared_prefix_len(&self, levels: &[Level]) -> usize {
        let mut cur: Option<usize> = None;
        let mut len = 0;
        for level in levels {
            let next = {
                let slot = match cur {
                    None => &self.roots,
                    Some(p) => &self.nodes[p].children,
                };
                slot.iter().copied().find(|&c| self.nodes[c].level == *level)
            };
            match next {
                Some(c) => {
                    cur = Some(c);
                    len += 1;
                }
                None => break,
            }
        }
        len
    }

    /// Route `plan` through the trie, reusing equal-op prefixes and
    /// creating nodes for the unshared suffix.
    fn insert(&mut self, pattern_idx: usize, plan: &Plan) {
        let mut cur: Option<usize> = None;
        for level in &plan.levels {
            let found = {
                let slot = match cur {
                    None => &self.roots,
                    Some(p) => &self.nodes[p].children,
                };
                slot.iter().copied().find(|&c| self.nodes[c].level == *level)
            };
            let node = match found {
                Some(c) => c,
                None => {
                    let id = self.nodes.len();
                    self.nodes.push(FusedNode {
                        level: level.clone(),
                        children: Vec::new(),
                        emit: Vec::new(),
                    });
                    match cur {
                        None => self.roots.push(id),
                        Some(p) => self.nodes[p].children.push(id),
                    }
                    id
                }
            };
            cur = Some(node);
        }
        self.nodes[cur.expect("plans have at least one level")]
            .emit
            .push(pattern_idx);
    }

    /// Number of fused patterns.
    pub fn num_patterns(&self) -> usize {
        self.plans.len()
    }

    /// How many whole-graph first-level sweeps the fused executor performs
    /// (the per-pattern path performs one per pattern).
    pub fn first_level_traversals(&self) -> usize {
        self.roots.len()
    }

    /// Total levels across the per-pattern plans — what the per-pattern
    /// path executes.
    pub fn total_plan_levels(&self) -> usize {
        self.plans.iter().map(|p| p.levels.len()).sum()
    }

    /// Plan levels eliminated by trie sharing.
    pub fn shared_levels(&self) -> usize {
        self.total_plan_levels() - self.nodes.len()
    }

    /// Deepest plan length (executor buffer count).
    pub fn max_depth(&self) -> usize {
        self.plans.iter().map(|p| p.levels.len()).max().unwrap_or(0)
    }

    /// One-line sharing summary for reports.
    pub fn describe(&self) -> String {
        format!(
            "fused {} patterns: {} trie nodes for {} plan levels ({} shared), {} first-level sweep(s)",
            self.num_patterns(),
            self.nodes.len(),
            self.total_plan_levels(),
            self.shared_levels(),
            self.first_level_traversals(),
        )
    }
}

/// Candidate plans for one pattern: the default greedy order first, then
/// every edge-connected order up to the size cap. Symmetry conditions and
/// |Aut| are order-independent — computed once, reused by every candidate.
fn candidate_plans(p: &Pattern) -> Vec<Plan> {
    let default = Plan::compile(p);
    let conds = symmetry::breaking_conditions(p);
    let aut_count = default.aut_count;
    let mut plans = Vec::with_capacity(8);
    for order in connected_orders(p, order_cap(p.num_vertices())) {
        if order == default.order {
            continue;
        }
        plans.push(Plan::with_order_and_conds(p, order, &conds, aut_count));
    }
    plans.insert(0, default);
    plans
}

/// Enumerate matching orders whose every prefix is edge-connected, in
/// lexicographic vertex order, stopping at `cap`.
fn connected_orders(p: &Pattern, cap: usize) -> Vec<Vec<usize>> {
    fn rec(p: &Pattern, order: &mut Vec<usize>, out: &mut Vec<Vec<usize>>, cap: usize) {
        if out.len() >= cap {
            return;
        }
        let n = p.num_vertices();
        if order.len() == n {
            out.push(order.clone());
            return;
        }
        for v in 0..n {
            if order.contains(&v) {
                continue;
            }
            if !order.is_empty() && !order.iter().any(|&u| p.has_edge(u, v)) {
                continue;
            }
            order.push(v);
            rec(p, order, out, cap);
            order.pop();
        }
    }
    let mut out = Vec::new();
    rec(p, &mut Vec::with_capacity(p.num_vertices()), &mut out, cap);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::{catalog, gen};

    fn counting() -> CostParams {
        CostParams::counting()
    }

    #[test]
    fn motif_base_set_shares_one_root() {
        // the 6 connected 4-vertex edge-induced patterns — the naive-PMR
        // base set for 4-motif counting
        let base = gen::connected_patterns(4);
        assert_eq!(base.len(), 6);
        let fused = FusedPlan::build(&base, None, &counting());
        assert_eq!(fused.num_patterns(), 6);
        assert_eq!(
            fused.first_level_traversals(),
            1,
            "unlabeled sets share the level-0 sweep: {}",
            fused.describe()
        );
        assert!(
            fused.shared_levels() > 0,
            "4-motif plans must share interior levels: {}",
            fused.describe()
        );
        assert!(fused.nodes.len() < fused.total_plan_levels());
    }

    #[test]
    fn plans_stay_aligned_with_input_order() {
        let base = vec![
            catalog::cycle(4),
            catalog::triangle(),
            catalog::clique(4),
            catalog::path(3),
        ];
        let fused = FusedPlan::build(&base, None, &counting());
        for (p, plan) in base.iter().zip(&fused.plans) {
            assert_eq!(p.canonical_key(), plan.pattern.canonical_key());
            assert_eq!(plan.order.len(), p.num_vertices());
        }
    }

    #[test]
    fn every_pattern_emits_exactly_once() {
        let base = catalog::motifs_vertex_induced(4);
        let fused = FusedPlan::build(&base, None, &counting());
        let mut seen = vec![0usize; base.len()];
        for node in &fused.nodes {
            for &i in &node.emit {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "emits: {seen:?}");
    }

    #[test]
    fn emit_depth_matches_plan_length() {
        let base = vec![catalog::triangle(), catalog::path(3), Pattern::empty(1)];
        let fused = FusedPlan::build(&base, None, &counting());
        // walk the trie, recording each emit's depth
        fn walk(f: &FusedPlan, node: usize, depth: usize, out: &mut Vec<(usize, usize)>) {
            for &i in &f.nodes[node].emit {
                out.push((i, depth + 1));
            }
            for &c in &f.nodes[node].children {
                walk(f, c, depth + 1, out);
            }
        }
        let mut emits = Vec::new();
        for &r in &fused.roots {
            walk(&fused, r, 0, &mut emits);
        }
        assert_eq!(emits.len(), base.len());
        for (i, depth) in emits {
            assert_eq!(depth, fused.plans[i].levels.len(), "pattern {i}");
        }
    }

    #[test]
    fn subset_build_drops_masked_patterns() {
        let base = gen::connected_patterns(4);
        let mut keep = vec![true; base.len()];
        keep[0] = false;
        keep[3] = false;
        let (fused, selected) = FusedPlan::build_for_subset(&base, &keep, None, &counting());
        assert_eq!(fused.num_patterns(), base.len() - 2);
        assert_eq!(selected.len(), base.len() - 2);
        for (slot, &orig) in selected.iter().enumerate() {
            assert!(keep[orig]);
            assert_eq!(
                fused.plans[slot].pattern.canonical_key(),
                base[orig].canonical_key(),
                "plan slot {slot} must hold original pattern {orig}"
            );
        }
        // an all-false mask yields an empty plan, an all-true mask the
        // identical pattern set as a direct build
        let (empty, sel) =
            FusedPlan::build_for_subset(&base, &vec![false; base.len()], None, &counting());
        assert_eq!(empty.num_patterns(), 0);
        assert!(sel.is_empty());
        let (full, sel) =
            FusedPlan::build_for_subset(&base, &vec![true; base.len()], None, &counting());
        assert_eq!(full.num_patterns(), base.len());
        assert_eq!(sel, (0..base.len()).collect::<Vec<_>>());
    }

    #[test]
    fn labeled_level0_splits_roots() {
        let a = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[0, 1]);
        let b = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[2, 3]);
        let fused = FusedPlan::build(&[a, b], None, &counting());
        assert_eq!(fused.first_level_traversals(), 2);
    }

    #[test]
    fn connected_orders_are_connected_and_capped() {
        let p = catalog::tailed_triangle();
        let orders = connected_orders(&p, 1000);
        assert!(!orders.is_empty());
        for o in &orders {
            for i in 1..o.len() {
                assert!(
                    o[..i].iter().any(|&u| p.has_edge(u, o[i])),
                    "disconnected prefix in {o:?}"
                );
            }
        }
        let capped = connected_orders(&p, 3);
        assert_eq!(capped.len(), 3);
    }
}
