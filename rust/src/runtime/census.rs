//! Dense census backend: the coordinator-facing wrapper around the AOT
//! census executables.
//!
//! The backend owns one compiled executable per artifact size and serves
//! motif-census requests for (sub)graphs that fit a padded adjacency block.
//! It is the Layer-1/2 counterpart of the sparse Rust matcher — the same
//! morphing equations evaluated by dense linear algebra — and doubles as an
//! independent cross-check oracle in the integration tests.

use crate::graph::{DataGraph, VertexId};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Output layout of the census vector — must match `model.OUTPUTS`.
pub const CENSUS_OUTPUTS: [&str; 11] = [
    "vertices",
    "edges",
    "wedge_vi",
    "triangle",
    "star4_vi",
    "path4_vi",
    "tailed_triangle_vi",
    "cycle4_vi",
    "diamond_vi",
    "clique4",
    "cycle5_e",
];

/// Parsed census result.
#[derive(Clone, Debug, PartialEq)]
pub struct CensusResult {
    pub values: Vec<f64>,
}

impl CensusResult {
    pub fn get(&self, name: &str) -> Option<f64> {
        CENSUS_OUTPUTS
            .iter()
            .position(|&o| o == name)
            .map(|i| self.values[i])
    }

    /// Vertex-induced 4-motif counts in census order
    /// (star, path, tailed, cycle, diamond, clique) — see
    /// [`census_motifs4`] for the corresponding patterns.
    pub fn motifs4(&self) -> [f64; 6] {
        [
            self.values[4],
            self.values[5],
            self.values[6],
            self.values[7],
            self.values[8],
            self.values[9],
        ]
    }
}

/// The vertex-induced 4-motifs in the census output order.
pub fn census_motifs4() -> [crate::pattern::Pattern; 6] {
    use crate::pattern::catalog;
    [
        catalog::star(4).vertex_induced(),
        catalog::path(4).vertex_induced(),
        catalog::tailed_triangle().vertex_induced(),
        catalog::cycle(4).vertex_induced(),
        catalog::diamond().vertex_induced(),
        catalog::clique(4),
    ]
}

/// The vertex-induced 3-motifs in the census output order.
pub fn census_motifs3() -> [crate::pattern::Pattern; 2] {
    use crate::pattern::catalog;
    [catalog::path(3).vertex_induced(), catalog::triangle()]
}

/// The dense census backend.
pub struct CensusBackend {
    runtime: super::Runtime,
    sizes: Vec<usize>,
    executables: Vec<super::Executable>,
}

impl CensusBackend {
    /// Load all `census_<N>.hlo.txt` artifacts from `dir` (ascending N).
    pub fn load(dir: &Path) -> Result<CensusBackend> {
        let runtime = super::Runtime::cpu()?;
        let mut found: Vec<(usize, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("reading artifacts dir {}", dir.display()))?
        {
            let path = entry?.path();
            let name = path.file_name().unwrap_or_default().to_string_lossy().into_owned();
            if let Some(num) = name
                .strip_prefix("census_")
                .and_then(|s| s.strip_suffix(".hlo.txt"))
            {
                found.push((num.parse().context("artifact size suffix")?, path));
            }
        }
        if found.is_empty() {
            bail!(
                "no census_<N>.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            );
        }
        found.sort();
        let mut sizes = Vec::new();
        let mut executables = Vec::new();
        for (n, path) in found {
            executables.push(runtime.load_hlo_text(&path)?);
            sizes.push(n);
        }
        Ok(CensusBackend {
            runtime,
            sizes,
            executables,
        })
    }

    /// Largest graph the backend can census.
    pub fn max_size(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Census of a whole graph (must fit the largest artifact).
    pub fn census_graph(&self, g: &DataGraph) -> Result<CensusResult> {
        let block: Vec<VertexId> = (0..g.num_vertices() as VertexId).collect();
        self.census_block(g, &block)
    }

    /// Census of the subgraph induced by `block`.
    pub fn census_block(&self, g: &DataGraph, block: &[VertexId]) -> Result<CensusResult> {
        let k = block.len();
        let idx = self
            .sizes
            .iter()
            .position(|&n| n >= k)
            .with_context(|| {
                format!("graph with {k} vertices exceeds artifact size {}", self.max_size())
            })?;
        let n = self.sizes[idx];
        let dense = g.densify(block);
        // pad k×k into n×n
        let mut a = vec![0f64; n * n];
        for i in 0..k {
            for j in 0..k {
                a[i * n + j] = dense[i * k + j] as f64;
            }
        }
        let out = self.executables[idx].run_f64(&[(&a, &[n as i64, n as i64])])?;
        if out.len() != CENSUS_OUTPUTS.len() {
            bail!(
                "census output length {} != expected {}",
                out.len(),
                CENSUS_OUTPUTS.len()
            );
        }
        Ok(CensusResult { values: out })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::morph::Policy;

    fn backend() -> Option<CensusBackend> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("census_64.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(CensusBackend::load(&dir).unwrap())
    }

    #[test]
    fn dense_census_agrees_with_sparse_matcher() {
        let Some(be) = backend() else { return };
        let g = erdos_renyi(48, 140, 77);
        let dense = be.census_graph(&g).unwrap();
        let sparse = crate::apps::count_motifs(&g, 4, Policy::Off, 2);
        let got = dense.motifs4();
        for (i, m) in super::census_motifs4().iter().enumerate() {
            assert_eq!(
                got[i].round() as u64,
                sparse.get(m).unwrap(),
                "motif {i} ({m:?}) dense vs sparse"
            );
        }
        assert_eq!(dense.get("edges").unwrap() as usize, g.num_edges());
    }

    #[test]
    fn census_block_subgraph() {
        let Some(be) = backend() else { return };
        let g = erdos_renyi(200, 900, 78);
        let block: Vec<u32> = (0..50).collect();
        let r = be.census_block(&g, &block).unwrap();
        assert!(r.get("edges").unwrap() >= 0.0);
        assert!(r.get("vertices").unwrap() <= 50.0);
    }

    #[test]
    fn oversized_graph_rejected() {
        let Some(be) = backend() else { return };
        let g = erdos_renyi(be.max_size() + 1, 600, 79);
        assert!(be.census_graph(&g).is_err());
    }
}
