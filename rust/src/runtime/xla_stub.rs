//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The dense census backend is written against the `xla` crate's PJRT API,
//! but that crate (and its `xla_extension` native library) is not available
//! in this build environment. This module mirrors the handful of types and
//! methods [`super`] uses so the crate always compiles; every entry point
//! fails at [`PjRtClient::cpu`] with a clear error, which the coordinator
//! surfaces as "dense backend unavailable" and falls back to the sparse
//! matcher. Swap this module for the real crate by deleting the `mod xla`
//! declaration in `runtime/mod.rs` and adding the dependency.

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug)]
pub struct XlaError(String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built with the offline xla stub (no xla_extension library)"
            .to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}
