//! PJRT runtime: loads the AOT-compiled census artifacts (HLO text produced
//! by `python/compile/aot.py`) and executes them from the Rust side.
//!
//! Python never runs on the query path: `make artifacts` lowers the Layer-2
//! JAX model once; this module compiles the HLO with the PJRT CPU client at
//! startup and serves census requests from the mining coordinator.

mod census;
/// Offline stand-in for the `xla` crate (see the module docs in
/// `xla_stub.rs`): same API surface, every entry point errors at
/// `PjRtClient::cpu`. Remove this declaration and add the real dependency
/// when `xla_extension` is available.
#[path = "xla_stub.rs"]
mod xla;

pub use census::{census_motifs3, census_motifs4, CensusBackend, CensusResult, CENSUS_OUTPUTS};

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled PJRT executable loaded from HLO text.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

/// Shared PJRT client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text module.
    ///
    /// HLO *text* is the interchange format: jax ≥ 0.5 serialized protos use
    /// 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids (see /opt/xla-example/README.md).
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

impl Executable {
    /// Execute with f64 input buffers (each given as flat data + dims),
    /// returning the flattened f64 output of the 1-tuple result.
    pub fn run_f64(&self, inputs: &[(&[f64], &[i64])]) -> Result<Vec<f64>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        out.to_vec::<f64>().context("reading f64 output")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("census_64.hlo.txt").exists().then_some(p)
    }

    #[test]
    fn runtime_loads_and_runs_census() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
        let exe = rt.load_hlo_text(&dir.join("census_64.hlo.txt")).unwrap();
        // K4 in the top-left corner of a 64×64 zero matrix
        let n = 64usize;
        let mut a = vec![0f64; n * n];
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    a[i * n + j] = 1.0;
                }
            }
        }
        let out = exe.run_f64(&[(&a, &[n as i64, n as i64])]).unwrap();
        // OUTPUTS: [vertices, edges, wedge_vi, triangle, star4_vi, path4_vi,
        //           tailed_vi, cycle4_vi, diamond_vi, clique4, cycle5_e]
        assert_eq!(out[0], 4.0, "vertices");
        assert_eq!(out[1], 6.0, "edges");
        assert_eq!(out[3], 4.0, "triangles");
        assert_eq!(out[9], 1.0, "clique4");
        assert_eq!(out[7], 0.0, "cycle4_vi");
    }
}
