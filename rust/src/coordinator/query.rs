//! Query descriptions: a parsed, serializable form of what the CLI / bench
//! harness asks the coordinator to do.

use crate::pattern::{catalog, parse, Pattern};
use anyhow::{bail, Result};

/// A mining query.
#[derive(Clone, Debug)]
pub enum Query {
    /// Count all motifs of a size (3–5).
    Motifs { size: usize },
    /// Match a set of patterns (count unique matches).
    Match { patterns: Vec<Pattern> },
    /// Frequent subgraph mining.
    Fsm { max_edges: usize, support: u64 },
    /// k-clique counting.
    Cliques { k: usize },
}

impl Query {
    /// Parse a query string:
    /// `motifs:4`, `match:cycle4-vi,p3`, `fsm:3:300`, `cliques:4`.
    pub fn parse(s: &str) -> Result<Query> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or_default();
        match kind {
            "motifs" => {
                let size: usize = parts
                    .next()
                    .unwrap_or("4")
                    .parse()?;
                if !(3..=5).contains(&size) {
                    bail!("motif size must be 3..=5, got {size}");
                }
                Ok(Query::Motifs { size })
            }
            "match" => {
                let spec = parts.next().unwrap_or_default();
                if spec.is_empty() {
                    bail!("match query needs patterns: match:<p1>,<p2>,…");
                }
                let patterns = spec
                    .split(',')
                    .map(parse::parse)
                    .collect::<Result<Vec<_>>>()?;
                Ok(Query::Match { patterns })
            }
            "fsm" => {
                let max_edges: usize = parts.next().unwrap_or("3").parse()?;
                let support: u64 = parts.next().unwrap_or("100").parse()?;
                Ok(Query::Fsm { max_edges, support })
            }
            "cliques" => {
                let k: usize = parts.next().unwrap_or("4").parse()?;
                Ok(Query::Cliques { k })
            }
            other => bail!("unknown query kind {other:?}"),
        }
    }

    /// Expand to the pattern set whose **unique-match counts** answer this
    /// query, in reporting order: the vertex-induced motif set for
    /// `motifs:<n>`, the query patterns for `match:…`, the `k`-clique for
    /// `cliques:<k>`. Returns `None` for FSM — its support aggregation is
    /// level-wise, not per-pattern, so it cannot be served from a
    /// per-base-pattern result cache ([`crate::service`]).
    pub fn patterns(&self) -> Option<Vec<Pattern>> {
        match self {
            Query::Motifs { size } => Some(catalog::motifs_vertex_induced(*size)),
            Query::Match { patterns } => Some(patterns.clone()),
            Query::Cliques { k } => Some(vec![catalog::clique(*k)]),
            Query::Fsm { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert!(matches!(Query::parse("motifs:4").unwrap(), Query::Motifs { size: 4 }));
        assert!(matches!(Query::parse("cliques:5").unwrap(), Query::Cliques { k: 5 }));
        match Query::parse("fsm:3:250").unwrap() {
            Query::Fsm { max_edges, support } => {
                assert_eq!((max_edges, support), (3, 250));
            }
            _ => panic!(),
        }
        match Query::parse("match:cycle4,p3").unwrap() {
            Query::Match { patterns } => assert_eq!(patterns.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn patterns_expansion() {
        assert_eq!(Query::parse("motifs:4").unwrap().patterns().unwrap().len(), 6);
        assert_eq!(Query::parse("match:cycle4,p3").unwrap().patterns().unwrap().len(), 2);
        let k = Query::parse("cliques:4").unwrap().patterns().unwrap();
        assert_eq!(k.len(), 1);
        assert!(k[0].is_clique());
        assert!(Query::parse("fsm:3:100").unwrap().patterns().is_none());
    }

    #[test]
    fn parse_rejects_bad() {
        assert!(Query::parse("motifs:9").is_err());
        assert!(Query::parse("match:").is_err());
        assert!(Query::parse("bogus:1").is_err());
    }
}
