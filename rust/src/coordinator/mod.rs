//! Mining coordinator — the Layer-3 leader that owns a loaded data graph,
//! plans queries through the morphing engine, dispatches matching work to
//! the thread pool or the dense XLA backend, and reports phase metrics
//! (matching vs aggregation, the Figure-2 breakdown).

pub mod query;

use crate::apps::{self, FsmConfig, FsmResult, MatchResult, MotifCounts};
use crate::graph::{DataGraph, GraphStats};
use crate::morph::Policy;
use crate::runtime::CensusBackend;
use anyhow::Result;
use std::path::PathBuf;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Worker threads for the sparse matcher.
    pub threads: usize,
    /// Morphing policy.
    pub policy: Policy,
    /// Where the AOT census artifacts live (`None` = sparse only).
    pub artifacts_dir: Option<PathBuf>,
    /// Use the dense XLA backend for whole-graph motif censuses when the
    /// graph fits an artifact.
    pub allow_dense: bool,
    /// Fuse multi-pattern base sets into one shared-prefix traversal.
    pub fused: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            threads: crate::exec::parallel::default_threads(),
            policy: Policy::CostBased,
            artifacts_dir: None,
            allow_dense: true,
            fused: true,
        }
    }
}

/// Which backend served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Sparse pattern-aware matcher (Rust, Layer 3).
    Sparse,
    /// Dense XLA census (Layers 1–2 via PJRT).
    DenseXla,
}

/// The mining coordinator.
pub struct Coordinator {
    graph: DataGraph,
    config: Config,
    census: Option<CensusBackend>,
    stats: std::sync::OnceLock<GraphStats>,
}

impl Coordinator {
    /// Create a coordinator; loads census artifacts if configured. A dense
    /// backend that fails to load (e.g. the offline `xla` stub, or missing
    /// artifacts) is reported and the coordinator falls back to the sparse
    /// matcher rather than failing construction.
    pub fn new(graph: DataGraph, config: Config) -> Result<Coordinator> {
        let census = match &config.artifacts_dir {
            Some(dir) if config.allow_dense => match CensusBackend::load(dir) {
                Ok(be) => Some(be),
                Err(e) => {
                    eprintln!("dense backend unavailable, using sparse matcher: {e:#}");
                    None
                }
            },
            _ => None,
        };
        Ok(Coordinator {
            graph,
            config,
            census,
            stats: std::sync::OnceLock::new(),
        })
    }

    pub fn graph(&self) -> &DataGraph {
        &self.graph
    }

    pub fn config(&self) -> &Config {
        &self.config
    }

    /// Graph statistics (computed once, shared by cost models and reports).
    pub fn stats(&self) -> &GraphStats {
        self.stats
            .get_or_init(|| GraphStats::compute(&self.graph, 2000, 0xC00D))
    }

    /// Does the dense backend apply to this request?
    fn dense_applicable(&self, size: usize) -> bool {
        matches!(&self.census, Some(be) if size <= 4
            && self.graph.num_vertices() <= be.max_size())
    }

    /// Motif counting with automatic backend selection. Returns the counts
    /// and which backend served them.
    pub fn motifs(&self, size: usize) -> Result<(MotifCounts, Backend)> {
        if self.dense_applicable(size) {
            let be = self.census.as_ref().unwrap();
            let mut profile = crate::util::timer::PhaseProfile::new();
            let r = profile.time("census", || be.census_graph(&self.graph))?;
            let (motifs, vals): (Vec<crate::pattern::Pattern>, Vec<f64>) = match size {
                3 => (
                    crate::runtime::census_motifs3().to_vec(),
                    vec![r.get("wedge_vi").unwrap(), r.get("triangle").unwrap()],
                ),
                4 => (
                    crate::runtime::census_motifs4().to_vec(),
                    r.motifs4().to_vec(),
                ),
                _ => unreachable!(),
            };
            let counts = motifs
                .into_iter()
                .zip(vals)
                .map(|(p, v)| (p, v.round() as u64))
                .collect();
            return Ok((
                MotifCounts {
                    counts,
                    profile,
                    base: Vec::new(),
                },
                Backend::DenseXla,
            ));
        }
        Ok((
            apps::count_motifs_opts(&self.graph, size, self.config.policy, self.exec_opts()),
            Backend::Sparse,
        ))
    }

    /// Execution options derived from the config. The coordinator's shared
    /// [`GraphStats`] ride along so fused order selection and cost-based
    /// PMR price plans with the same model.
    fn exec_opts(&self) -> crate::morph::ExecOpts {
        crate::morph::ExecOpts::new(self.config.threads)
            .with_fused(self.config.fused)
            .with_stats(self.stats().clone())
    }

    /// Pattern matching through the morphing engine.
    pub fn match_patterns(&self, queries: &[crate::pattern::Pattern]) -> MatchResult {
        apps::match_patterns_opts(&self.graph, queries, self.config.policy, self.exec_opts())
    }

    /// Frequent subgraph mining.
    pub fn fsm(&self, max_edges: usize, support: u64) -> FsmResult {
        apps::fsm(
            &self.graph,
            &FsmConfig {
                max_edges,
                support,
                policy: self.config.policy,
                threads: self.config.threads,
                fused: self.config.fused,
            },
        )
    }

    /// k-clique counting.
    pub fn cliques(&self, k: usize) -> u64 {
        apps::count_cliques(&self.graph, k, self.config.threads)
    }

    /// One-line summary for reports.
    pub fn describe(&self) -> String {
        let s = self.stats();
        format!(
            "{}: |V|={} |E|={} maxdeg={} avgdeg={:.1} labels={} policy={:?} threads={} fused={} dense={}",
            self.graph.name(),
            s.num_vertices,
            s.num_edges,
            s.max_degree,
            s.avg_degree,
            self.graph.num_labels(),
            self.config.policy,
            self.config.threads,
            self.config.fused,
            self.census.is_some(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{assign_labels, erdos_renyi};

    fn artifacts() -> Option<PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("census_64.hlo.txt").exists().then_some(p)
    }

    #[test]
    fn coordinator_sparse_roundtrip() {
        let g = assign_labels(erdos_renyi(60, 200, 81), 4, 1.4, 82);
        let c = Coordinator::new(g, Config {
            artifacts_dir: None,
            threads: 2,
            ..Config::default()
        })
        .unwrap();
        let (m, backend) = c.motifs(4).unwrap();
        assert_eq!(backend, Backend::Sparse);
        assert_eq!(m.counts.len(), 6);
        let fs = c.fsm(2, 2);
        assert!(!fs.levels.is_empty());
        assert!(c.cliques(3) > 0);
        assert!(c.describe().contains("|V|=60"));
    }

    #[test]
    fn coordinator_dense_backend_selected_and_agrees() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let g = erdos_renyi(50, 170, 83);
        let dense = Coordinator::new(
            g.clone(),
            Config {
                artifacts_dir: Some(dir),
                threads: 2,
                ..Config::default()
            },
        )
        .unwrap();
        let sparse = Coordinator::new(g, Config {
            artifacts_dir: None,
            threads: 2,
            ..Config::default()
        })
        .unwrap();
        let (md, bd) = dense.motifs(4).unwrap();
        let (ms, bs) = sparse.motifs(4).unwrap();
        assert_eq!(bd, Backend::DenseXla);
        assert_eq!(bs, Backend::Sparse);
        for (p, a) in &md.counts {
            assert_eq!(Some(*a), ms.get(p), "{p:?}");
        }
        // 3-motifs via dense too
        let (m3, b3) = dense.motifs(3).unwrap();
        assert_eq!(b3, Backend::DenseXla);
        assert_eq!(m3.counts.len(), 2);
    }

    #[test]
    fn dense_skipped_when_too_large() {
        let Some(dir) = artifacts() else { return };
        let g = erdos_renyi(500, 1500, 84);
        let c = Coordinator::new(
            g,
            Config {
                artifacts_dir: Some(dir),
                threads: 2,
                ..Config::default()
            },
        )
        .unwrap();
        let (_, backend) = c.motifs(4).unwrap();
        assert_eq!(backend, Backend::Sparse);
    }
}
