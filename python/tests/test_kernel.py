"""Layer-1 correctness: the Pallas census kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, block sizes and value distributions; exact
agreement is required for 0/1 inputs (integer-valued f64 arithmetic) and
allclose for general floats.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels.census import masked_matmul  # noqa: E402
from compile.kernels.ref import masked_matmul_ref, random_adjacency  # noqa: E402


@pytest.mark.parametrize("n,block", [(4, 4), (8, 4), (16, 8), (32, 32), (64, 32)])
def test_kernel_matches_ref_adjacency(n, block):
    rng = np.random.default_rng(n * 31 + block)
    a = random_adjacency(rng, n, 0.4).astype(np.float64)
    c, b = masked_matmul(a, a, a, block=block)
    cr, br = masked_matmul_ref(a, a, a)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(br))


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    bs=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31),
    density=st.floats(0.05, 0.9),
)
def test_kernel_hypothesis_adjacency(n_blocks, bs, seed, density):
    n = n_blocks * bs
    rng = np.random.default_rng(seed)
    a = random_adjacency(rng, n, density).astype(np.float64)
    c, b = masked_matmul(a, a, a, block=bs)
    cr, br = masked_matmul_ref(a, a, a)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(b), np.asarray(br))


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 2),
    bs=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31),
)
def test_kernel_hypothesis_general_floats(n_blocks, bs, seed):
    """Distinct X, Y, M operands (the 5-cycle pass uses C, C, A)."""
    n = n_blocks * bs
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, n))
    y = rng.standard_normal((n, n))
    m = random_adjacency(rng, n, 0.5).astype(np.float64)
    c, b = masked_matmul(x, y, m, block=bs)
    cr, br = masked_matmul_ref(x, y, m)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-12)


def test_kernel_rejects_ragged():
    a = np.zeros((6, 6))
    with pytest.raises(AssertionError):
        masked_matmul(a, a, a, block=4)  # 6 % 4 != 0


def test_kernel_single_block_path():
    a = np.eye(8)[::-1]  # permutation matrix
    c, b = masked_matmul(a, a, a, block=8)
    np.testing.assert_array_equal(np.asarray(c), np.eye(8))
    np.testing.assert_array_equal(np.asarray(b), np.eye(8) * a)
