"""Layer-2 correctness: the dense census (with in-graph morphing equations)
vs exhaustive enumeration on tiny random graphs."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def census_np(a, pad=16):
    """Run the census on a padded copy of a small adjacency matrix."""
    n = a.shape[0]
    p = np.zeros((pad, pad), dtype=np.float64)
    p[:n, :n] = a
    out = np.asarray(model.census(p))
    return {name: out[i] for i, name in enumerate(model.OUTPUTS)}


def test_conversion_matrices_structure():
    # unit diagonal, upper-triangular in edge-count order
    for u in (model.U3, model.U4):
        assert np.all(np.diag(u) == 1)
        assert np.allclose(u, np.triu(u))
    # the famous Fig. 4 coefficient: 3 unique 4-cycles per 4-clique
    i = list(ref.MOTIFS4).index("cycle4")
    j = list(ref.MOTIFS4).index("clique4")
    assert model.U4[i, j] == 3
    # 4 unique tailed triangles per diamond (paper Fig. 6)
    i = list(ref.MOTIFS4).index("tailed_triangle")
    j = list(ref.MOTIFS4).index("diamond")
    assert model.U4[i, j] == 4


def test_known_small_graphs():
    # K4
    k4 = np.ones((4, 4)) - np.eye(4)
    c = census_np(k4)
    assert c["edges"] == 6
    assert c["triangle"] == 4
    assert c["clique4"] == 1
    assert c["cycle4_vi"] == 0
    assert c["diamond_vi"] == 0
    assert c["wedge_vi"] == 0
    # C5
    c5 = np.zeros((5, 5))
    for i in range(5):
        c5[i, (i + 1) % 5] = c5[(i + 1) % 5, i] = 1
    c = census_np(c5)
    assert c["cycle5_e"] == 1
    assert c["triangle"] == 0
    assert c["path4_vi"] == 5
    # star
    s = np.zeros((5, 5))
    s[0, 1:] = s[1:, 0] = 1
    c = census_np(s)
    assert c["star4_vi"] == 4  # C(4,3) claws
    assert c["wedge_vi"] == 6


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(5, 10),
    density=st.floats(0.15, 0.7),
    seed=st.integers(0, 2**31),
)
def test_census_matches_brute_force(n, density, seed):
    rng = np.random.default_rng(seed)
    a = ref.random_adjacency(rng, n, density)
    c = census_np(a)
    bf3 = ref.brute_force_motifs(a, 3)
    assert c["wedge_vi"] == bf3["wedge"]
    assert c["triangle"] == bf3["triangle"]
    bf4 = ref.brute_force_motifs(a, 4)
    assert c["star4_vi"] == bf4["star4"]
    assert c["path4_vi"] == bf4["path4"]
    assert c["tailed_triangle_vi"] == bf4["tailed_triangle"]
    assert c["cycle4_vi"] == bf4["cycle4"]
    assert c["diamond_vi"] == bf4["diamond"]
    assert c["clique4"] == bf4["clique4"]


@settings(max_examples=6, deadline=None)
@given(n=st.integers(5, 8), seed=st.integers(0, 2**31))
def test_cycle5_matches_enumeration(n, seed):
    rng = np.random.default_rng(seed)
    a = ref.random_adjacency(rng, n, 0.5)
    c = census_np(a)
    # count 5-cycles by brute force: closed 5-walks with distinct vertices
    import itertools

    count = 0
    for sub in itertools.permutations(range(n), 5):
        if sub[0] != min(sub):
            continue
        if sub[1] > sub[4]:  # canonical direction
            continue
        ok = all(a[sub[i], sub[(i + 1) % 5]] for i in range(5))
        count += ok
    assert c["cycle5_e"] == count


def test_padding_invariance():
    rng = np.random.default_rng(1234)
    a = ref.random_adjacency(rng, 7, 0.5)
    c16 = census_np(a, pad=16)
    c24 = census_np(a, pad=24)
    for k in model.OUTPUTS:
        assert c16[k] == pytest.approx(c24[k]), k


def test_edges_and_vertices_reported():
    rng = np.random.default_rng(99)
    a = ref.random_adjacency(rng, 9, 0.4)
    c = census_np(a)
    assert c["edges"] == a.sum() / 2
    assert c["vertices"] == np.sum(a.sum(1) > 0)
