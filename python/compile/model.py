"""Layer-2 JAX model: dense adjacency-matrix motif census with the paper's
morphing equations applied in-graph.

Given a padded 0/1 adjacency matrix ``A`` (f64, zero diagonal), the model
computes **edge-induced** counts of all 3- and 4-vertex connected patterns
(plus the 5-cycle) from closed-walk / degree identities driven by the
Layer-1 masked-matmul kernel, then converts them to **vertex-induced** motif
counts by inverting the Match Conversion Theorem's linear system
(Theorem 3.1: ``counts_E = U · counts_V`` where ``U[p][q]`` is the number of
unique embeddings ``φ(p^E, q^E)/|Aut(p)|`` — the Fig. 4 coefficients).

The conversion matrix is derived *independently* of the Rust implementation
(brute force over permutations in ``kernels.ref``), so the Rust↔XLA
cross-check in ``rust/tests`` validates two separately-derived
implementations of the same theorem.

Output vector layout: see ``OUTPUTS``.
"""

import numpy as np
import jax.numpy as jnp

from .kernels import ref
from .kernels.census import masked_matmul

OUTPUTS = [
    "vertices",          # number of non-isolated... no: n with padding = row count of ones? -> num vertices with degree>0
    "edges",
    "wedge_vi",          # vertex-induced 3-motifs
    "triangle",
    "star4_vi",          # vertex-induced 4-motifs (order matches ref.MOTIFS4)
    "path4_vi",
    "tailed_triangle_vi",
    "cycle4_vi",
    "diamond_vi",
    "clique4",
    "cycle5_e",          # edge-induced 5-cycle count (Table 1's p7)
]

_MOTIF4_NAMES = list(ref.MOTIFS4.keys())


def _conversion_matrix(motifs, n_pat):
    """U[p][q] = unique embeddings of p's edge set into q's edge set
    (same vertex count). Upper-triangular in edge-count order, unit
    diagonal — invertible over the integers."""
    names = list(motifs.keys())
    k = len(names)
    u = np.zeros((k, k), dtype=np.int64)
    for i, pi in enumerate(names):
        for j, qj in enumerate(names):
            if len(motifs[qj]) >= len(motifs[pi]):
                u[i, j] = ref.unique_embeddings(motifs[pi], motifs[qj], n_pat)
    return u


# Derived once at import; tiny (≤ 4! per entry).
U3 = _conversion_matrix(ref.MOTIFS3, 3)
U4 = _conversion_matrix(ref.MOTIFS4, 4)
U3_INV = np.linalg.inv(U3)
U4_INV = np.linalg.inv(U4)


def census(a):
    """Compute the census vector for a padded adjacency matrix ``a``.

    Returns an f64 vector aligned with ``OUTPUTS``.
    """
    a = a.astype(jnp.float64)
    d = a.sum(axis=1)

    # --- kernel pass 1: C = A@A, B = C∘A ------------------------------
    c, b = masked_matmul(a, a, a)

    n_active = jnp.sum(d > 0).astype(jnp.float64)
    m = d.sum() / 2.0

    # 3-vertex counts
    wedges_e = jnp.sum(d * (d - 1.0)) / 2.0          # Σ C(d,2)
    triangles = jnp.sum(b) / 6.0                      # tr(A³)/6

    # 4-vertex edge-induced counts
    star4_e = jnp.sum(d * (d - 1.0) * (d - 2.0)) / 6.0  # Σ C(d,3)
    # paths on 4 vertices: Σ_{(i,j)∈E}(d_i−1)(d_j−1) − 3T
    dm1 = d - 1.0
    path4_e = (jnp.einsum("ij,i,j->", a, dm1, dm1) / 2.0) - 3.0 * triangles
    # tailed triangles: Σ_v t_v (d_v − 2), t_v = per-vertex triangles
    t_v = b.sum(axis=1) / 2.0
    tailed_e = jnp.sum(t_v * (d - 2.0))
    # 4-cycles: (tr A⁴ − 2m − 4W)/8, tr A⁴ = Σ C²
    tr_a4 = jnp.sum(c * c)
    cycle4_e = (tr_a4 - 2.0 * m - 4.0 * wedges_e) / 8.0
    # diamonds (edge-induced): Σ_{edges} C(t_e, 2), t_e = B_ij
    diamond_e = jnp.sum(b * (b - 1.0)) / 4.0  # /2 per pair, /2 double count
    # 4-cliques: (1/24) Σ A_ij A_ik A_il A_jk A_jl A_kl — contract k then l
    # P_ijl = Σ_k A_ik A_jk A_kl  (only needed where A_ij A_il A_jl = 1)
    p_ijl = jnp.einsum("ik,jk,kl->ijl", a, a, a)
    clique4 = jnp.einsum("ijl,ij,il,jl->", p_ijl, a, a, a) / 24.0

    # --- kernel pass 2: 5-cycles need (C@C)∘A --------------------------
    _, e5 = masked_matmul(c, c, a)
    tr_a5 = jnp.sum(e5)  # Σ_ij (A²A²)_ij A_ji = tr(A⁵)
    cycle5_e = (tr_a5 - 30.0 * triangles - 10.0 * tailed_e) / 10.0

    # --- morphing: edge-induced → vertex-induced -----------------------
    # NOTE: the conversion is unrolled to scalar multiply-adds instead of a
    # constant matvec (`U_INV @ counts`): xla_extension 0.5.1 — the runtime
    # behind the Rust `xla` crate — silently evaluates dots against large
    # constant operands to zero after the HLO-text round-trip. Scalar
    # constants survive. (Verified in /tmp repro; see DESIGN.md §Runtime.)
    def _convert(u_inv, counts):
        out = []
        for i in range(u_inv.shape[0]):
            acc = None
            for j in range(u_inv.shape[1]):
                cij = float(u_inv[i, j])
                if cij == 0.0:
                    continue
                term = cij * counts[j]
                acc = term if acc is None else acc + term
            out.append(acc)
        return out

    counts3_e = [wedges_e, triangles]
    counts3_v = _convert(U3_INV, counts3_e)
    counts4_e = [star4_e, path4_e, tailed_e, cycle4_e, diamond_e, clique4]
    counts4_v = _convert(U4_INV, counts4_e)

    # All outputs are integer counts mathematically; round away any f64
    # reassociation drift introduced by the XLA optimizer (observed ~1e-9
    # relative error on Σ C(d,3)-style reductions in xla_extension 0.5.1).
    return jnp.round(jnp.stack(
        [
            n_active,
            m,
            counts3_v[0],   # wedge_vi
            counts3_e[1],   # triangle (clique: E == V)
            counts4_v[0],   # star4_vi
            counts4_v[1],   # path4_vi
            counts4_v[2],   # tailed_triangle_vi
            counts4_v[3],   # cycle4_vi
            counts4_v[4],   # diamond_vi
            counts4_e[5],   # clique4
            cycle5_e,
        ]
    ))


def census_output_index(name):
    return OUTPUTS.index(name)
