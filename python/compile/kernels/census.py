"""Layer-1 Pallas kernel: blocked masked-matmul census.

The dense motif census (Layer 2, ``model.py``) is built from two primitives
over the adjacency matrix ``A``:

* ``C = X @ Y``            (walk counting), and
* ``B = C ∘ M``            (edge masking — restrict walk counts to edges),

fused into one Pallas kernel so the mask never re-reads ``C`` from HBM.
``masked_matmul(X, Y, M)`` returns ``(C, B)``; the census calls it with
``(A, A, A)`` for triangles/diamonds and ``(C, C, A)`` for 5-cycles.

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles HBM→VMEM with
``BlockSpec`` at ``(BM, BK) × (BK, BN)`` granularity and accumulates over the
``k`` grid axis, which is exactly the MXU-friendly schedule; the mask fuse
happens on the final ``k`` step while the accumulator tile is still resident
in VMEM. On this CPU image the kernel runs with ``interpret=True`` (Mosaic
custom-calls cannot execute on the CPU PJRT plugin); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: 128 matches the MXU systolic array edge; smaller matrices fall
# back to a single block.
DEFAULT_BLOCK = 128


def _census_kernel(x_ref, y_ref, m_ref, c_ref, b_ref, *, nk: int):
    """One (i, j, k) grid step: accumulate X_ik @ Y_kj into C_ij; on the last
    k step, emit the masked tile B_ij = C_ij * M_ij."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += x_ref[...] @ y_ref[...]

    @pl.when(k == nk - 1)
    def _mask():
        b_ref[...] = c_ref[...] * m_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def masked_matmul(x, y, m, *, block: int = DEFAULT_BLOCK):
    """Fused ``(x @ y, (x @ y) * m)`` via a blocked Pallas kernel.

    All inputs must be square ``(n, n)`` with ``n`` divisible by the block
    size (the census pads adjacency matrices to the artifact size).
    """
    n = x.shape[0]
    assert x.shape == y.shape == m.shape == (n, n), (x.shape, y.shape, m.shape)
    bs = min(block, n)
    assert n % bs == 0, f"n={n} not divisible by block={bs}"
    nk = n // bs
    grid = (n // bs, n // bs, nk)
    out_shape = (
        jax.ShapeDtypeStruct((n, n), x.dtype),
        jax.ShapeDtypeStruct((n, n), x.dtype),
    )
    c, b = pl.pallas_call(
        functools.partial(_census_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, k)),  # X_ik
            pl.BlockSpec((bs, bs), lambda i, j, k: (k, j)),  # Y_kj
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),  # M_ij
        ],
        out_specs=(
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),  # C_ij
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),  # B_ij
        ),
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, m)
    return c, b
