"""Pure-jnp / pure-python oracles for the Layer-1 kernel and Layer-2 model.

* ``masked_matmul_ref`` — the kernel's correctness reference.
* ``brute_force_motifs`` — exact unique-subgraph motif counts by exhaustive
  enumeration (tiny graphs only); the model's correctness reference.
* ``unique_embeddings`` — `|φ(p, q)| / |Aut(p)|`, used to derive the
  morphing conversion matrix independently of the Rust implementation.
"""

import itertools

import jax.numpy as jnp
import numpy as np


def masked_matmul_ref(x, y, m):
    """Reference for kernels.census.masked_matmul."""
    c = jnp.matmul(x, y)
    return c, c * m


# ---------------------------------------------------------------------------
# tiny-graph pattern utilities (numpy, exhaustive — test oracles only)
# ---------------------------------------------------------------------------

# 3- and 4-motif edge lists, ordered by edge count (must stay aligned with
# model.MOTIFS3 / model.MOTIFS4)
MOTIFS3 = {
    "wedge": [(0, 1), (1, 2)],
    "triangle": [(0, 1), (1, 2), (2, 0)],
}

MOTIFS4 = {
    "star4": [(0, 1), (0, 2), (0, 3)],
    "path4": [(0, 1), (1, 2), (2, 3)],
    "tailed_triangle": [(0, 1), (1, 2), (2, 0), (2, 3)],
    "cycle4": [(0, 1), (1, 2), (2, 3), (3, 0)],
    "diamond": [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)],
    "clique4": [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],
}


def _adj_of(n, edges):
    a = np.zeros((n, n), dtype=np.int64)
    for u, v in edges:
        a[u, v] = a[v, u] = 1
    return a


def automorphism_count(n, edges):
    """|Aut(p)| by brute force over permutations."""
    a = _adj_of(n, edges)
    count = 0
    for perm in itertools.permutations(range(n)):
        p = np.array(perm)
        if np.array_equal(a[np.ix_(p, p)], a):
            count += 1
    return count


def unique_embeddings(p_edges, q_edges, n):
    """Unique embeddings of edge-set p into edge-set q on the same n
    vertices: |{σ : E(p)^σ ⊆ E(q)}| / |Aut(p)|."""
    ap = _adj_of(n, p_edges)
    aq = _adj_of(n, q_edges)
    maps = 0
    for perm in itertools.permutations(range(n)):
        p = np.array(perm)
        # σ maps p-vertex i to q-vertex perm[i]: check E(p) ⊆ E(q)^σ
        if np.all(aq[np.ix_(p, p)] >= ap):
            maps += 1
    return maps // automorphism_count(n, p_edges)


def edge_induced_counts(adj, motifs, n_pat):
    """Exact unique edge-induced subgraph counts of each motif, by
    enumerating vertex subsets and sub-edge-sets. Tiny graphs only."""
    n = adj.shape[0]
    out = {}
    for name, edges in motifs.items():
        ap = _adj_of(n_pat, edges)
        count = 0
        for sub in itertools.combinations(range(n), n_pat):
            seen = set()
            for perm in itertools.permutations(sub):
                p = np.array(perm)
                if np.all(adj[np.ix_(p, p)] >= ap):
                    # record the edge image to count unique subgraphs
                    img = frozenset(
                        (min(p[u], p[v]), max(p[u], p[v])) for u, v in edges
                    )
                    seen.add(img)
            count += len(seen)
        out[name] = count
    return out


def vertex_induced_counts(adj, motifs, n_pat):
    """Exact unique vertex-induced subgraph counts (induced-subgraph
    isomorphism per vertex subset)."""
    n = adj.shape[0]
    out = {name: 0 for name in motifs}
    pats = {name: _adj_of(n_pat, edges) for name, edges in motifs.items()}
    for sub in itertools.combinations(range(n), n_pat):
        induced = adj[np.ix_(sub, sub)]
        for name, ap in pats.items():
            ok = any(
                np.array_equal(induced[np.ix_(np.array(p), np.array(p))], ap)
                for p in itertools.permutations(range(n_pat))
            )
            if ok:
                out[name] += 1
                break  # induced structure matches exactly one motif
    return out


def brute_force_motifs(adj, size):
    """Vertex-induced motif counts for `size` in {3, 4}."""
    motifs = MOTIFS3 if size == 3 else MOTIFS4
    return vertex_induced_counts(adj, motifs, size)


def random_adjacency(rng, n, p):
    """Symmetric 0/1 adjacency with edge probability p, zero diagonal."""
    a = (rng.random((n, n)) < p).astype(np.int64)
    a = np.triu(a, 1)
    return a + a.T
