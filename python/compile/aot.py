"""AOT compile path: lower the Layer-2 census model to HLO **text** for the
Rust PJRT runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts [--sizes 64,128]

Writes ``census_<N>.hlo.txt`` plus a small manifest describing the output
vector layout for the Rust side.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_census(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float64)
    lowered = jax.jit(lambda a: (model.census(a),)).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="64,128")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    sizes = [int(s) for s in args.sizes.split(",") if s]
    for n in sizes:
        text = lower_census(n)
        path = os.path.join(args.out_dir, f"census_{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    manifest = {
        "outputs": model.OUTPUTS,
        "sizes": sizes,
        "dtype": "f64",
        "note": "input: padded 0/1 adjacency (n,n), zero diagonal",
    }
    mpath = os.path.join(args.out_dir, "census_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
